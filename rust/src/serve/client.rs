//! Blocking client for the streamed serving protocol: connect, submit
//! (single or batch, with optional deadline), cancel, ping, goodbye.
//!
//! One background reader thread demultiplexes response frames to
//! per-request channels by id, so any number of requests can be in
//! flight concurrently over the single connection. Used by the
//! `stream_clients` load generator and the loopback e2e tests; it is
//! also the reference implementation for writing clients in other
//! languages.

use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::{Shutdown, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::wire::{self, Frame, FrameReader, Payload, Status, WHOLE_REQUEST};
use crate::util::Rng;

/// One response event as seen by a client: either a sample result
/// (`status == Ok`, `slot` = sample index) or a request-level outcome
/// (`slot == WHOLE_REQUEST`).
#[derive(Debug, Clone)]
pub struct WireResponse {
    /// The request id this event answers.
    pub id: u64,
    /// Sample index inside the request, or `WHOLE_REQUEST`.
    pub slot: u32,
    /// Outcome for this slot (or the whole request).
    pub status: Status,
    /// Argmax class of the logits (0 on non-`Ok` statuses).
    pub predicted: u16,
    /// Microseconds the sample waited in a shard queue.
    pub queue_us: u32,
    /// Microseconds the worker spent computing the sample.
    pub service_us: u32,
    /// Fraction of MACs the pruned plan skipped for this sample.
    pub mac_skipped: f32,
    /// The raw logits (empty on non-`Ok` statuses).
    pub logits: Vec<f32>,
}

struct Pending {
    tx: Sender<WireResponse>,
    /// `Ok` responses still expected before the entry retires.
    remaining: usize,
}

/// The adaptive control plane's state as answered to a `SetBudget`
/// frame. `scale_q8 == 0` means the server runs no adaptive control.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AdminStats {
    /// Active threshold scale in Q8.8 (256 = 1.0; 0 = not adaptive).
    pub scale_q8: u32,
    /// Active scale-grid step for the reported model.
    pub step: u32,
    /// The scale grid's total step count.
    pub steps_total: u32,
    /// The reported model's energy budget (mJ/inference).
    pub budget_mj: f64,
    /// EWMA of observed per-request energy (mJ).
    pub ewma_mj: f64,
    /// Calibrated whole-model keep ratio at the active step.
    pub keep_ratio: f32,
    /// Plan-cache hits since control-plane install.
    pub cache_hits: u64,
    /// Plan-cache misses (inline compiles) since install.
    pub cache_misses: u64,
    /// Plan swaps since install (inline + background upgrades).
    pub swaps: u64,
    /// Background compiles queued or in flight on the governor's
    /// compile thread (gauge).
    pub bg_pending: u64,
    /// Background compiles completed since governor install.
    pub bg_compiled: u64,
    /// Background compiles that upgraded the live plan slot.
    pub bg_upgrades: u64,
    /// Worker panics contained by the coordinator's supervisor.
    pub worker_panics: u64,
    /// Workers respawned with fresh scratch after a contained panic.
    pub respawns: u64,
    /// Sustained keep-ratio divergences flagged by the drift tracker.
    pub drift_trips: u64,
    /// Live profile re-measurements completed after drift trips.
    pub recalibrations: u64,
    /// Which model this report covers (v4; 0 from a v3 server).
    pub model: u32,
    /// Models hosted by the server (v4; 0 from a v3 server).
    pub models_loaded: u32,
    /// Fleet-wide energy budget being divided by the scheduler (v4; 0
    /// without a scheduler).
    pub fleet_budget_mj: f64,
}

impl AdminStats {
    /// Whether the server reported an attached adaptive governor.
    pub fn adaptive(&self) -> bool {
        self.scale_q8 != 0
    }

    /// The scale as a real value (0.0 when not adaptive).
    pub fn scale(&self) -> f64 {
        self.scale_q8 as f64 / 256.0
    }
}

struct ClientShared {
    pending: Mutex<HashMap<u64, Pending>>,
    pongs: Mutex<HashMap<u64, Sender<()>>>,
    stats: Mutex<HashMap<u64, Sender<AdminStats>>>,
    /// Text-bodied admin replies in flight (`Scrape` / `TraceDump`).
    texts: Mutex<HashMap<u64, Sender<String>>>,
    /// Server said goodbye (or the connection died).
    closed: AtomicBool,
    goodbye_tx: Mutex<Option<Sender<()>>>,
}

/// Blocking protocol client. Cheap to share behind an `Arc`; all
/// methods take `&self`.
pub struct Client {
    writer: Mutex<TcpStream>,
    shared: Arc<ClientShared>,
    next_id: AtomicU64,
    reader: Option<JoinHandle<()>>,
    goodbye_rx: Mutex<Receiver<()>>,
}

impl Client {
    /// Connect and start the demultiplexing reader thread.
    pub fn connect<A: ToSocketAddrs>(addr: A) -> std::io::Result<Client> {
        let stream = TcpStream::connect(addr)?;
        let _ = stream.set_nodelay(true);
        // Symmetric to the server's SessionCfg::write_timeout: a
        // stalled peer must error a blocked send rather than wedge the
        // writer mutex (and with it cancel/ping/goodbye/Drop) forever.
        stream.set_write_timeout(Some(Duration::from_secs(5)))?;
        let read_half = stream.try_clone()?;
        let (goodbye_tx, goodbye_rx) = channel();
        let shared = Arc::new(ClientShared {
            pending: Mutex::new(HashMap::new()),
            pongs: Mutex::new(HashMap::new()),
            stats: Mutex::new(HashMap::new()),
            texts: Mutex::new(HashMap::new()),
            closed: AtomicBool::new(false),
            goodbye_tx: Mutex::new(Some(goodbye_tx)),
        });
        let t_shared = Arc::clone(&shared);
        let reader = std::thread::spawn(move || reader_loop(read_half, t_shared));
        Ok(Client {
            writer: Mutex::new(stream),
            shared,
            next_id: AtomicU64::new(1),
            reader: Some(reader),
            goodbye_rx: Mutex::new(goodbye_rx),
        })
    }

    fn send(&self, frame: &Frame) -> std::io::Result<()> {
        let bytes = wire::encode(frame);
        let mut w = self.writer.lock().unwrap();
        w.write_all(&bytes)?;
        w.flush()
    }

    /// Next client-chosen request id (unique per connection).
    pub fn fresh_id(&self) -> u64 {
        self.next_id.fetch_add(1, Ordering::Relaxed)
    }

    /// Submit one sample to model `0` (the single-model default). The
    /// receiver yields exactly one event: the `Ok` result, or a
    /// request-level status (rejected/expired/…).
    pub fn submit(
        &self,
        x: &[f32],
        deadline: Option<Duration>,
    ) -> std::io::Result<(u64, Receiver<WireResponse>)> {
        self.submit_payload(Payload::F32(x.to_vec()), x.len(), 0, deadline)
    }

    /// Submit one sample addressed to a specific model on a
    /// multi-model server (wire v4).
    pub fn submit_to(
        &self,
        model: u32,
        x: &[f32],
        deadline: Option<Duration>,
    ) -> std::io::Result<(u64, Receiver<WireResponse>)> {
        self.submit_payload(Payload::F32(x.to_vec()), x.len(), model, deadline)
    }

    /// Submit a batch to model `0` (`xs` must share one length; ragged
    /// batches are rejected with `InvalidInput`). The receiver streams
    /// one event per sample in slot order, or a single request-level
    /// status.
    pub fn submit_batch(
        &self,
        xs: &[Vec<f32>],
        deadline: Option<Duration>,
    ) -> std::io::Result<(u64, Receiver<WireResponse>)> {
        self.submit_batch_to(0, xs, deadline)
    }

    /// Submit a batch addressed to a specific model (wire v4).
    pub fn submit_batch_to(
        &self,
        model: u32,
        xs: &[Vec<f32>],
        deadline: Option<Duration>,
    ) -> std::io::Result<(u64, Receiver<WireResponse>)> {
        let sample_len = xs.first().map_or(0, |x| x.len());
        if xs.iter().any(|x| x.len() != sample_len) {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidInput,
                "ragged batch: samples differ in length",
            ));
        }
        let flat: Vec<f32> = xs.iter().flat_map(|x| x.iter().copied()).collect();
        self.submit_payload(Payload::F32(flat), sample_len, model, deadline)
    }

    /// Submit pre-quantized i8 samples to model `0` (`v / 127.0`
    /// dequantization server-side) — the compact transport.
    pub fn submit_i8(
        &self,
        flat: &[i8],
        sample_len: usize,
        deadline: Option<Duration>,
    ) -> std::io::Result<(u64, Receiver<WireResponse>)> {
        self.submit_payload(Payload::I8(flat.to_vec()), sample_len, 0, deadline)
    }

    fn submit_payload(
        &self,
        data: Payload,
        sample_len: usize,
        model: u32,
        deadline: Option<Duration>,
    ) -> std::io::Result<(u64, Receiver<WireResponse>)> {
        // Catch ragged or oversized input here with an Err: an
        // inconsistent (or length-capped) frame on the wire would be a
        // protocol error that kills the whole session and every other
        // in-flight request on it.
        if sample_len == 0 || data.is_empty() || data.len() % sample_len != 0 {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidInput,
                format!("{} values do not split into samples of {sample_len}", data.len()),
            ));
        }
        // Header (16) + request fields (16) + data + CRC (4) must fit
        // the decoder's MAX_FRAME_LEN; split bigger batches.
        let frame_len = wire::HEADER_LEN + 16 + data.byte_len() + 4;
        if frame_len > wire::MAX_FRAME_LEN {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidInput,
                format!(
                    "request frame of {frame_len} bytes exceeds the {} byte protocol cap; \
                     split the batch",
                    wire::MAX_FRAME_LEN
                ),
            ));
        }
        let id = self.fresh_id();
        let n_samples = data.len() / sample_len;
        let (tx, rx) = channel();
        // Register before sending: a reply can arrive arbitrarily fast.
        self.shared
            .pending
            .lock()
            .unwrap()
            .insert(id, Pending { tx, remaining: n_samples.max(1) });
        // Re-check closed AFTER the insert: the reader's shutdown path
        // stores `closed` and then clears `pending`, so any
        // interleaving either lands here (we remove and error) or the
        // reader's clear disconnects the receiver — a submit racing a
        // server goodbye can never strand a forever-pending entry.
        if self.shared.closed.load(Ordering::Acquire) {
            self.shared.pending.lock().unwrap().remove(&id);
            return Err(std::io::Error::new(
                std::io::ErrorKind::NotConnected,
                "connection closed by server",
            ));
        }
        let deadline_ms = deadline.map_or(0, |d| d.as_millis().min(u32::MAX as u128) as u32);
        let frame = Frame::Request { id, deadline_ms, sample_len: sample_len as u32, model, data };
        if let Err(e) = self.send(&frame) {
            self.shared.pending.lock().unwrap().remove(&id);
            return Err(e);
        }
        Ok((id, rx))
    }

    /// Cancel request `id`: queued work is dropped server-side and all
    /// its remaining replies are suppressed (silence, not a status).
    ///
    /// The pending entry is retired immediately — the contract is that
    /// nothing more arrives for `id`, so keeping it would leak one
    /// entry per cancel on a long-lived connection. The request's
    /// receiver disconnects; replies that were already in flight when
    /// the cancel was sent are discarded by the demultiplexer.
    pub fn cancel(&self, id: u64) -> std::io::Result<()> {
        let r = self.send(&Frame::Cancel { id });
        self.shared.pending.lock().unwrap().remove(&id);
        r
    }

    /// Admin: set the server's fleet-wide energy budget (mJ/inference)
    /// and return the control plane's resulting state. Check
    /// [`AdminStats::adaptive`] on the answer — a server without a
    /// governor or scheduler answers with the disabled shape instead of
    /// an error.
    pub fn set_budget(&self, budget_mj: f64, timeout: Duration) -> std::io::Result<AdminStats> {
        self.admin_roundtrip(budget_mj, wire::FLEET_MODEL, timeout)
    }

    /// Admin: cap one tenant's budget on a multi-model server (wire
    /// v4). The reply reports that model's allocation.
    pub fn set_model_budget(
        &self,
        model: u32,
        budget_mj: f64,
        timeout: Duration,
    ) -> std::io::Result<AdminStats> {
        self.admin_roundtrip(budget_mj, model, timeout)
    }

    /// Admin: query the control plane's state without changing any
    /// budget.
    pub fn query_stats(&self, timeout: Duration) -> std::io::Result<AdminStats> {
        self.admin_roundtrip(0.0, wire::FLEET_MODEL, timeout)
    }

    /// Admin: query one model's allocation on a multi-model server.
    pub fn query_model_stats(&self, model: u32, timeout: Duration) -> std::io::Result<AdminStats> {
        self.admin_roundtrip(0.0, model, timeout)
    }

    /// Admin: declare (or replace) one tenant's service-level
    /// objectives on the server (wire v6). Components `<= 0` disable
    /// that objective. Answered with that model's stats (the
    /// `SetBudget` idiom); a server without an SLO engine treats the
    /// frame as a plain stats query.
    pub fn set_slo(
        &self,
        model: u32,
        p99_ms: f64,
        keep_floor: f32,
        err_ceiling: f32,
        timeout: Duration,
    ) -> std::io::Result<AdminStats> {
        self.stats_roundtrip(
            |id| Frame::SetSlo { id, model, p99_ms, keep_floor, err_ceiling },
            timeout,
        )
    }

    fn admin_roundtrip(
        &self,
        budget_mj: f64,
        model: u32,
        timeout: Duration,
    ) -> std::io::Result<AdminStats> {
        self.stats_roundtrip(|id| Frame::SetBudget { id, budget_mj, model }, timeout)
    }

    /// Send one Stats-answered admin frame and wait for the reply.
    fn stats_roundtrip(
        &self,
        make: impl FnOnce(u64) -> Frame,
        timeout: Duration,
    ) -> std::io::Result<AdminStats> {
        let id = self.fresh_id();
        let (tx, rx) = channel();
        self.shared.stats.lock().unwrap().insert(id, tx);
        if let Err(e) = self.send(&make(id)) {
            self.shared.stats.lock().unwrap().remove(&id);
            return Err(e);
        }
        let out = rx.recv_timeout(timeout).map_err(|_| {
            std::io::Error::new(std::io::ErrorKind::TimedOut, "no Stats reply")
        });
        self.shared.stats.lock().unwrap().remove(&id);
        out
    }

    /// Admin: pull a Prometheus text-format scrape of the server's
    /// full metric set over the serving connection (wire v5).
    pub fn scrape(&self, timeout: Duration) -> std::io::Result<String> {
        self.text_roundtrip(Frame::Scrape { id: 0, body: String::new() }, timeout)
    }

    /// Admin: pull a Chrome trace-event JSON dump of the server's
    /// flight recorder (wire v5). An empty `traceEvents` array means
    /// the server runs with observability off.
    pub fn trace_dump(&self, timeout: Duration) -> std::io::Result<String> {
        self.text_roundtrip(Frame::TraceDump { id: 0, body: String::new() }, timeout)
    }

    fn text_roundtrip(&self, mut frame: Frame, timeout: Duration) -> std::io::Result<String> {
        let id = self.fresh_id();
        match &mut frame {
            Frame::Scrape { id: fid, .. } | Frame::TraceDump { id: fid, .. } => *fid = id,
            _ => unreachable!("text_roundtrip only carries Scrape/TraceDump"),
        }
        let (tx, rx) = channel();
        self.shared.texts.lock().unwrap().insert(id, tx);
        if let Err(e) = self.send(&frame) {
            self.shared.texts.lock().unwrap().remove(&id);
            return Err(e);
        }
        let out = rx.recv_timeout(timeout).map_err(|_| {
            std::io::Error::new(std::io::ErrorKind::TimedOut, "no scrape/trace reply")
        });
        self.shared.texts.lock().unwrap().remove(&id);
        out
    }

    /// Liveness probe: true iff the server echoed within `timeout`.
    pub fn ping(&self, timeout: Duration) -> bool {
        let id = self.fresh_id();
        let (tx, rx) = channel();
        self.shared.pongs.lock().unwrap().insert(id, tx);
        if self.send(&Frame::Ping { id }).is_err() {
            self.shared.pongs.lock().unwrap().remove(&id);
            return false;
        }
        let ok = rx.recv_timeout(timeout).is_ok();
        self.shared.pongs.lock().unwrap().remove(&id);
        ok
    }

    /// True once the server said goodbye or the connection died.
    pub fn is_closed(&self) -> bool {
        self.shared.closed.load(Ordering::Acquire)
    }

    /// Graceful close: send `Goodbye`, wait (up to `timeout`) for the
    /// server's goodbye after it drains our in-flight work. Returns
    /// whether the handshake completed.
    pub fn goodbye(mut self, timeout: Duration) -> bool {
        let _ = self.send(&Frame::Goodbye);
        let done = self.goodbye_rx.lock().unwrap().recv_timeout(timeout).is_ok();
        self.teardown();
        done
    }

    fn teardown(&mut self) {
        let _ = self.writer.lock().unwrap().shutdown(Shutdown::Both);
        if let Some(h) = self.reader.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Client {
    fn drop(&mut self) {
        self.teardown();
    }
}

fn reader_loop(mut stream: TcpStream, shared: Arc<ClientShared>) {
    let mut reader = FrameReader::new();
    let mut buf = vec![0u8; 64 * 1024];
    'outer: loop {
        match stream.read(&mut buf) {
            Ok(0) | Err(_) => break,
            Ok(n) => {
                reader.feed(&buf[..n]);
                loop {
                    match reader.next() {
                        Ok(Some(frame)) => handle_frame(&shared, frame),
                        Ok(None) => break,
                        Err(e) => {
                            eprintln!("[client] protocol error: {e}");
                            break 'outer;
                        }
                    }
                    if shared.closed.load(Ordering::Acquire) {
                        break 'outer;
                    }
                }
            }
        }
    }
    shared.closed.store(true, Ordering::Release);
    // Wake the goodbye waiter and fail over any outstanding requests:
    // dropping the senders makes every pending receiver disconnect.
    drop(shared.goodbye_tx.lock().unwrap().take());
    shared.pending.lock().unwrap().clear();
    shared.pongs.lock().unwrap().clear();
    shared.stats.lock().unwrap().clear();
    shared.texts.lock().unwrap().clear();
}

fn handle_frame(shared: &Arc<ClientShared>, frame: Frame) {
    match frame {
        Frame::Response {
            id,
            slot,
            status,
            predicted,
            queue_us,
            service_us,
            mac_skipped,
            logits,
        } => {
            let mut pending = shared.pending.lock().unwrap();
            let retire = match pending.get_mut(&id) {
                Some(entry) => {
                    let _ = entry.tx.send(WireResponse {
                        id,
                        slot,
                        status,
                        predicted,
                        queue_us,
                        service_us,
                        mac_skipped,
                        logits,
                    });
                    if status == Status::Ok && slot != WHOLE_REQUEST {
                        entry.remaining -= 1;
                        entry.remaining == 0
                    } else {
                        // Request-level outcome: no more events follow.
                        true
                    }
                }
                None => false, // late reply for a retired/cancelled id
            };
            if retire {
                pending.remove(&id);
            }
        }
        Frame::Pong { id } => {
            if let Some(tx) = shared.pongs.lock().unwrap().remove(&id) {
                let _ = tx.send(());
            }
        }
        Frame::Stats {
            id,
            scale_q8,
            step,
            steps_total,
            budget_mj,
            ewma_mj,
            keep_ratio,
            cache_hits,
            cache_misses,
            swaps,
            bg_pending,
            bg_compiled,
            bg_upgrades,
            worker_panics,
            respawns,
            drift_trips,
            recalibrations,
            model,
            models_loaded,
            fleet_budget_mj,
        } => {
            if let Some(tx) = shared.stats.lock().unwrap().remove(&id) {
                let _ = tx.send(AdminStats {
                    scale_q8,
                    step,
                    steps_total,
                    budget_mj,
                    ewma_mj,
                    keep_ratio,
                    cache_hits,
                    cache_misses,
                    swaps,
                    bg_pending,
                    bg_compiled,
                    bg_upgrades,
                    worker_panics,
                    respawns,
                    drift_trips,
                    recalibrations,
                    model,
                    models_loaded,
                    fleet_budget_mj,
                });
            }
        }
        Frame::Scrape { id, body } | Frame::TraceDump { id, body } => {
            if let Some(tx) = shared.texts.lock().unwrap().remove(&id) {
                let _ = tx.send(body);
            }
        }
        Frame::Goodbye => {
            shared.closed.store(true, Ordering::Release);
            if let Some(tx) = shared.goodbye_tx.lock().unwrap().take() {
                let _ = tx.send(());
            }
        }
        // Client-only frames from a server: ignore.
        Frame::Request { .. } | Frame::Cancel { .. } | Frame::Ping { .. }
        | Frame::SetBudget { .. } | Frame::SetSlo { .. } => {}
    }
}

// ---------------------------------------------------------------------------
// Retrying client

/// Retry policy for [`RetryClient`].
///
/// The default is 8 attempts with 25 ms base backoff doubling to a
/// 1 s ceiling; tune fields from the default rather than building the
/// struct from scratch:
///
/// ```
/// use std::time::Duration;
/// use unit_pruner::serve::RetryCfg;
///
/// let cfg = RetryCfg { max_attempts: 3, ..RetryCfg::default() };
/// assert_eq!(cfg.max_attempts, 3);
/// assert_eq!(cfg.base_backoff, Duration::from_millis(25));
/// assert_eq!(cfg.max_backoff, Duration::from_secs(1));
/// assert_eq!(cfg.seed, 1); // fixed jitter seed: chaos runs replay
/// ```
#[derive(Debug, Clone, Copy)]
pub struct RetryCfg {
    /// Total submission attempts per request (first try included).
    pub max_attempts: usize,
    /// First backoff; doubles per failed attempt (jittered ±50%).
    pub base_backoff: Duration,
    /// Backoff ceiling.
    pub max_backoff: Duration,
    /// Seed for the jitter stream — fixed so chaos runs replay
    /// identically; give concurrent clients distinct seeds to decorrelate
    /// their retry storms.
    pub seed: u64,
}

impl Default for RetryCfg {
    fn default() -> RetryCfg {
        RetryCfg {
            max_attempts: 8,
            base_backoff: Duration::from_millis(25),
            max_backoff: Duration::from_secs(1),
            seed: 1,
        }
    }
}

/// Self-healing wrapper over [`Client`]: reconnects on connection loss
/// and resubmits requests answered `Rejected` (backpressure) or
/// `Failed` (a contained worker panic), with jittered exponential
/// backoff between attempts. `Expired` is terminal — a lapsed deadline
/// must not be retried into a second bite at the budget — and the
/// overall deadline bounds the whole retry loop, sleeps included.
///
/// Requests are submitted one at a time (no pipelining): the point is
/// a correctness-first caller for chaos runs and scripts, not a load
/// generator.
pub struct RetryClient {
    addr: String,
    cfg: RetryCfg,
    inner: Mutex<Option<Client>>,
    rng: Mutex<Rng>,
}

impl RetryClient {
    /// Build the wrapper. No connection is attempted until the first
    /// request — a server that is still booting costs a backoff, not
    /// an error.
    pub fn connect(addr: impl Into<String>, cfg: RetryCfg) -> RetryClient {
        RetryClient {
            addr: addr.into(),
            cfg,
            inner: Mutex::new(None),
            rng: Mutex::new(Rng::new(cfg.seed ^ 0xC1A0_5EED)),
        }
    }

    /// Infer one sample, retrying through rejections, contained worker
    /// failures, and connection loss. Returns the final `Ok` (or
    /// `Expired`) event.
    pub fn infer(&self, x: &[f32], deadline: Option<Duration>) -> std::io::Result<WireResponse> {
        let mut out = self.infer_batch(std::slice::from_ref(&x.to_vec()), deadline)?;
        Ok(out.remove(0))
    }

    /// Infer a batch, retrying the whole batch on any retryable
    /// outcome. On success the returned events are in slot order,
    /// one per sample; a terminal `Expired` comes back as a single
    /// whole-request event.
    pub fn infer_batch(
        &self,
        xs: &[Vec<f32>],
        deadline: Option<Duration>,
    ) -> std::io::Result<Vec<WireResponse>> {
        let t0 = Instant::now();
        let mut last_err: Option<std::io::Error> = None;
        for attempt in 0..self.cfg.max_attempts {
            if attempt > 0 {
                let slept = self.backoff(attempt, deadline.map(|d| d.saturating_sub(t0.elapsed())));
                if !slept {
                    break; // deadline would lapse mid-backoff
                }
            }
            match self.try_once(xs, deadline, t0) {
                Attempt::Done(events) => return Ok(events),
                Attempt::Retry(e) => last_err = Some(e),
                Attempt::Fatal(e) => return Err(e),
            }
        }
        Err(last_err.unwrap_or_else(|| {
            std::io::Error::new(std::io::ErrorKind::TimedOut, "retry budget exhausted")
        }))
    }

    /// One submission attempt over the current (or a fresh) connection.
    fn try_once(
        &self,
        xs: &[Vec<f32>],
        deadline: Option<Duration>,
        t0: Instant,
    ) -> Attempt {
        let mut guard = self.inner.lock().unwrap();
        if guard.as_ref().is_none_or(|c| c.is_closed()) {
            match Client::connect(self.addr.as_str()) {
                Ok(c) => *guard = Some(c),
                Err(e) => {
                    *guard = None;
                    return Attempt::Retry(e);
                }
            }
        }
        let client = guard.as_ref().expect("connection just ensured");
        let rx = match client.submit_batch(xs, deadline.map(|d| d.saturating_sub(t0.elapsed()))) {
            Ok((_, rx)) => rx,
            Err(e) if e.kind() == std::io::ErrorKind::InvalidInput => return Attempt::Fatal(e),
            Err(e) => {
                *guard = None; // dead or closing connection: reconnect next attempt
                return Attempt::Retry(e);
            }
        };
        drop(guard);
        let mut events: Vec<WireResponse> = Vec::with_capacity(xs.len());
        loop {
            let wait = deadline
                .map(|d| d.saturating_sub(t0.elapsed()))
                .unwrap_or(Duration::from_secs(30));
            let ev = match rx.recv_timeout(wait) {
                Ok(ev) => ev,
                Err(_) => {
                    // Disconnected mid-stream (or the wait ran out):
                    // drop the connection and retry — a corrupted or
                    // lost reply is indistinguishable from a dead peer.
                    *self.inner.lock().unwrap() = None;
                    return Attempt::Retry(std::io::Error::new(
                        std::io::ErrorKind::ConnectionAborted,
                        "reply stream broke mid-request",
                    ));
                }
            };
            match ev.status {
                Status::Ok => {
                    // The server contract is contiguous slot order; a
                    // violation is a protocol bug, not chaos noise.
                    if ev.slot as usize != events.len() {
                        return Attempt::Fatal(std::io::Error::new(
                            std::io::ErrorKind::InvalidData,
                            format!("out-of-order slot {} (expected {})", ev.slot, events.len()),
                        ));
                    }
                    events.push(ev);
                    if events.len() == xs.len() {
                        return Attempt::Done(events);
                    }
                }
                // Backpressure (session window, or a tenant-scoped SLO
                // throttle) or a contained worker panic: resubmit —
                // the backoff is exactly the pacing a throttled tenant
                // is being asked for.
                Status::Rejected | Status::Failed | Status::Throttled => {
                    return Attempt::Retry(std::io::Error::new(
                        std::io::ErrorKind::Interrupted,
                        format!("request answered {:?}; resubmitting", ev.status),
                    ));
                }
                // The deadline lapsed server-side: terminal by design.
                Status::Expired => return Attempt::Done(vec![ev]),
                // The cancel contract is silence — an unsolicited
                // Cancelled is a protocol violation, not chaos noise.
                Status::Cancelled => {
                    return Attempt::Fatal(std::io::Error::new(
                        std::io::ErrorKind::InvalidData,
                        "server answered Cancelled for a request we never cancelled",
                    ));
                }
                Status::Error => {
                    return Attempt::Fatal(std::io::Error::new(
                        std::io::ErrorKind::InvalidInput,
                        "server answered Error (malformed request)",
                    ));
                }
            }
        }
    }

    /// Sleep the jittered exponential backoff for `attempt` (≥ 1).
    /// Returns false — without sleeping — when the remaining deadline
    /// cannot cover the sleep.
    fn backoff(&self, attempt: usize, remaining: Option<Duration>) -> bool {
        let exp = self
            .cfg
            .base_backoff
            .saturating_mul(1u32 << (attempt - 1).min(16) as u32)
            .min(self.cfg.max_backoff);
        let jitter = 0.5 + 0.5 * self.rng.lock().unwrap().f64();
        let sleep = exp.mul_f64(jitter);
        if let Some(rem) = remaining {
            if sleep >= rem {
                return false;
            }
        }
        std::thread::sleep(sleep);
        true
    }
}

/// Outcome of one [`RetryClient`] submission attempt.
enum Attempt {
    /// Final events (slot-ordered `Ok`s, or one terminal `Expired`).
    Done(Vec<WireResponse>),
    /// Retryable: backoff, then resubmit (reconnecting if needed).
    Retry(std::io::Error),
    /// Not retryable: caller bug or protocol violation.
    Fatal(std::io::Error),
}
