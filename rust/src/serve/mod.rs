//! Streamed serving: the socket front door of the coordinator.
//!
//! PR 2 left a serving stack that could only be driven by in-process
//! `submit`/`submit_batch` calls. This module makes it a servable
//! system: a TCP listener speaking a framed binary protocol, long-lived
//! client sessions with real flow control, and admission informed by
//! the execution plan's per-sample MAC estimates — the part that is
//! UnIT-specific, because input-dependent pruning makes per-request
//! cost vary with activation sparsity, so fair scheduling has to
//! reason about *work*, not request *count*.
//!
//! Layers, bottom-up:
//!
//! * [`wire`] — the pure frame codec (length-prefixed, CRC-checked,
//!   f32/i8 payloads). No I/O: property-testable in memory.
//! * [`session`] — one protocol state machine per connection: bounded
//!   in-flight window (credit-based backpressure), per-request
//!   deadlines enforced by one shared [`session::Reaper`] thread,
//!   cancellation that tombstones queued work and suppresses in-flight
//!   replies, ordered streaming of batch sub-replies, graceful drain.
//! * [`listener`] — the accept loop: session-thread-per-connection,
//!   connection cap, close-listener → drain-sessions → close-pool
//!   shutdown.
//! * [`client`] — the blocking reference client used by the
//!   `stream_clients` load generator and the loopback e2e tests, plus
//!   [`client::RetryClient`], the self-healing wrapper that reconnects
//!   and resubmits through `Rejected`/`Failed` outcomes with jittered
//!   exponential backoff.
//!
//! Everything is `std` (TcpListener/TcpStream + threads), matching the
//! rest of the crate: no async runtime in the vendored set, and none
//! needed at simulator throughputs.

pub mod client;
pub mod listener;
pub mod session;
pub mod wire;

pub use client::{AdminStats, Client, RetryCfg, RetryClient, WireResponse};
pub use listener::{ServeOpts, Server};
pub use session::{Reaper, SessionCfg, SessionExit, SessionHandle};
pub use wire::{Frame, FrameReader, Payload, Status, WireError, WHOLE_REQUEST};
