//! TCP accept loop: session-thread-per-connection serving with a
//! connection cap and ordered shutdown.
//!
//! Thread topology: one accept thread (non-blocking listener polled at
//! 5 ms so shutdown is prompt), one session thread per live
//! connection, one shared [`Reaper`] timer thread, plus the
//! coordinator's worker pool underneath.
//!
//! Shutdown mirrors [`crate::coordinator::ShardPool`]'s
//! close-then-drain protocol, one layer up:
//!
//! 1. **close the listener** — no new connections;
//! 2. **drain the sessions** — each stops admitting, finishes its
//!    in-flight work, answers `Goodbye`, exits;
//! 3. **close the pool** — the coordinator intake closes and workers
//!    drain whatever the sessions left queued, then join.
//!
//! Order matters: sessions can only finish in-flight work while the
//! workers are still alive, and the pool can only be closed safely
//! once no session will submit again (a session racing the close gets
//! `Err(Closed)` back and answers its client with an `Error` status —
//! never a panic; `tests/serve_wire.rs` pins this).

use std::io;
use std::net::{SocketAddr, TcpListener};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use super::session::{spawn_session, Reaper, SessionCfg, SessionHandle};
use super::wire::{self, Frame};
use crate::control::{FleetScheduler, Governor};
use crate::coordinator::{Coordinator, Metrics};
use crate::obs::SloEngine;
use crate::util::FaultPlan;

/// Listener configuration.
#[derive(Debug, Clone)]
pub struct ServeOpts {
    /// Max simultaneous sessions; extra connections get a `Goodbye`
    /// frame and are closed immediately.
    pub max_conns: usize,
    /// Per-session configuration.
    pub session: SessionCfg,
    /// Adaptive control plane, when the server runs one (built with
    /// `Governor::install` on the same coordinator *before* the server
    /// starts). Sessions answer `SetBudget`/`Stats` admin frames
    /// through it; `None` answers them with the "adaptive control
    /// disabled" Stats shape.
    pub governor: Option<Arc<Governor>>,
    /// Multi-model control plane, when the server hosts several models
    /// under one fleet budget (built with `FleetScheduler::install` on
    /// the same coordinator before the server starts). Mutually
    /// exclusive with `governor` in practice; when both are set the
    /// scheduler answers the admin frames.
    pub scheduler: Option<Arc<FleetScheduler>>,
    /// Deterministic fault-injection plan for chaos runs: sessions
    /// draw reply delays, frame corruption, and read stalls from it.
    /// Share the same `Arc` with `ServeConfig::fault` to also inject
    /// worker panics. `None` (the default) injects nothing.
    pub fault: Option<Arc<FaultPlan>>,
    /// Per-tenant SLO engine (burn-rate tracking + tripped-tenant
    /// admission). Sessions consult it on every request and route the
    /// wire `SetSlo` admin frame to it; `None` (the default) admits
    /// everything and answers `SetSlo` as a plain stats query.
    pub slo: Option<Arc<SloEngine>>,
}

impl Default for ServeOpts {
    fn default() -> ServeOpts {
        ServeOpts {
            max_conns: 64,
            session: SessionCfg::default(),
            governor: None,
            scheduler: None,
            fault: None,
            slo: None,
        }
    }
}

/// A listening streamed-serving server wrapped around a running
/// [`Coordinator`].
pub struct Server {
    coord: Arc<Coordinator>,
    local_addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept_handle: Option<JoinHandle<()>>,
    sessions: Arc<Mutex<Vec<SessionHandle>>>,
    reaper: Arc<Reaper>,
    /// Guards double-shutdown from the explicit path + `Drop`.
    finished: bool,
}

impl Server {
    /// Bind `addr` (e.g. `127.0.0.1:0` for an ephemeral port) and start
    /// accepting sessions that submit into `coord`.
    pub fn start(coord: Coordinator, addr: &str, opts: ServeOpts) -> io::Result<Server> {
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        let local_addr = listener.local_addr()?;
        let coord = Arc::new(coord);
        let reaper = Arc::new(Reaper::new());
        let stop = Arc::new(AtomicBool::new(false));
        let sessions: Arc<Mutex<Vec<SessionHandle>>> = Arc::default();

        let t_stop = Arc::clone(&stop);
        let t_sessions = Arc::clone(&sessions);
        let t_coord = Arc::clone(&coord);
        let t_reaper = Arc::clone(&reaper);
        let session_cfg = opts.session.clone();
        let governor = opts.governor.clone();
        let scheduler = opts.scheduler.clone();
        let fault = opts.fault.clone();
        let slo = opts.slo.clone();
        let max_conns = opts.max_conns.max(1);
        let accept_handle = std::thread::spawn(move || {
            accept_loop(
                listener, t_stop, t_sessions, t_coord, t_reaper, session_cfg, governor,
                scheduler, fault, slo, max_conns,
            )
        });

        Ok(Server {
            coord,
            local_addr,
            stop,
            accept_handle: Some(accept_handle),
            sessions,
            reaper,
            finished: false,
        })
    }

    /// The bound address (resolves ephemeral ports).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// The coordinator under this server (metrics, tests simulating
    /// pathological shutdown orders).
    pub fn coordinator(&self) -> &Arc<Coordinator> {
        &self.coord
    }

    /// The coordinator's metrics registry (shared with every session).
    pub fn metrics(&self) -> Arc<Metrics> {
        Arc::clone(&self.coord.metrics)
    }

    /// Live (not yet finished) sessions.
    pub fn session_count(&self) -> usize {
        self.sessions.lock().unwrap().iter().filter(|s| !s.is_finished()).count()
    }

    /// Graceful stop: close listener → drain sessions → close pool.
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        if self.finished {
            return;
        }
        self.finished = true;
        // 1. Close the listener.
        self.stop.store(true, Ordering::Release);
        if let Some(h) = self.accept_handle.take() {
            h.join().expect("accept thread panicked");
        }
        // 2. Drain the sessions (workers still alive underneath).
        let handles: Vec<SessionHandle> =
            std::mem::take(&mut *self.sessions.lock().unwrap());
        for s in &handles {
            s.begin_drain();
        }
        for s in handles {
            s.join();
        }
        self.reaper.shutdown();
        // 3. Close the pool and join the workers.
        self.coord.close();
        self.coord.join_workers();
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

#[allow(clippy::too_many_arguments)]
fn accept_loop(
    listener: TcpListener,
    stop: Arc<AtomicBool>,
    sessions: Arc<Mutex<Vec<SessionHandle>>>,
    coord: Arc<Coordinator>,
    reaper: Arc<Reaper>,
    session_cfg: SessionCfg,
    governor: Option<Arc<Governor>>,
    scheduler: Option<Arc<FleetScheduler>>,
    fault: Option<Arc<FaultPlan>>,
    slo: Option<Arc<SloEngine>>,
    max_conns: usize,
) {
    while !stop.load(Ordering::Acquire) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                let mut guard = sessions.lock().unwrap();
                // Reap finished session threads so the cap counts only
                // live connections and handles don't accumulate.
                guard.retain(|s| !s.is_finished());
                if guard.len() >= max_conns {
                    drop(guard);
                    // Over the cap: an immediate, well-formed refusal
                    // beats a silent RST. Half-close and briefly drain
                    // the read side — closing with unread pipelined
                    // bytes (a fast client's first Ping) would RST and
                    // could destroy the Goodbye in flight.
                    let mut stream = stream;
                    let _ = std::io::Write::write_all(
                        &mut stream,
                        &wire::encode(&Frame::Goodbye),
                    );
                    let _ = stream.shutdown(std::net::Shutdown::Write);
                    let _ = stream.set_read_timeout(Some(Duration::from_millis(100)));
                    let mut sink = [0u8; 1024];
                    for _ in 0..8 {
                        match std::io::Read::read(&mut stream, &mut sink) {
                            Ok(0) | Err(_) => break,
                            Ok(_) => {}
                        }
                    }
                    continue;
                }
                match spawn_session(
                    stream,
                    Arc::clone(&coord),
                    Arc::clone(&reaper),
                    session_cfg.clone(),
                    governor.clone(),
                    scheduler.clone(),
                    fault.clone(),
                    slo.clone(),
                ) {
                    Ok(handle) => guard.push(handle),
                    Err(e) => eprintln!("[serve] failed to start session: {e}"),
                }
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                // Reap finished sessions on the idle path too —
                // otherwise a dead session's write-half FD (and its
                // join handle) would be held until the next accept.
                sessions.lock().unwrap().retain(|s| !s.is_finished());
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(e) => {
                eprintln!("[serve] accept error: {e}");
                std::thread::sleep(Duration::from_millis(20));
            }
        }
    }
    // Listener drops here: the port closes before sessions drain.
}
