//! Client sessions: the long-lived protocol state machine between one
//! TCP connection and the coordinator.
//!
//! Each session owns:
//!
//! * an **in-flight window** — credit-based backpressure: at most
//!   `max_inflight` admitted-and-unfinished requests per session; a
//!   request arriving past the limit is answered immediately with a
//!   `Rejected` status frame and never touches a shard — unless a
//!   **park queue** is configured (`SessionCfg::park`), in which case
//!   up to that many overflow requests wait FIFO and are admitted as
//!   credits return (completion, cancel, expiry), their deadline
//!   clocks still running from frame receipt; parked payloads are held
//!   decoded, so `SessionCfg::park_bytes` optionally caps the queue's
//!   total decoded bytes alongside the entry count;
//! * **deadlines** — a per-request expiry registered with the shared
//!   [`Reaper`] (one monotonic timer thread for the whole server, not
//!   one per request). Expiry CASes the request's [`RequestCtl`] out of
//!   `Active`: queued samples become tombstones the workers drop at
//!   dequeue, in-flight samples get their replies suppressed, and the
//!   client receives a single `Expired` status frame;
//! * **cancellation** — a `Cancel` frame does the same CAS; no frame is
//!   sent back (the contract is silence: every sub-reply after the
//!   cancel is suppressed);
//! * **ordered streaming** — sub-replies of a batch are released in
//!   slot order (the session's stream sink parks out-of-order
//!   completions), so a client reading the stream sees slots `0..k`
//!   as a contiguous prefix;
//! * **model routing** — a v4 request names its target model; the
//!   session validates the id and the model's input length before
//!   admission, so an unknown tenant is a structured `Error` reply,
//!   never a worker-side surprise (v3 requests decode as model 0);
//! * **graceful drain** — on client `Goodbye`, listener shutdown, or
//!   disconnect: stop admitting, let in-flight work finish (bounded by
//!   `drain_timeout`), answer `Goodbye`, close;
//! * **failure reporting** — when a worker panics with one of this
//!   session's samples in flight, the coordinator's supervisor wins
//!   the ctl CAS and calls the sink's `fail()`: the client gets a
//!   single `Failed` status frame, the window credit returns, and no
//!   later sub-reply can contradict the outcome.
//!
//! The outcome race (completion vs deadline vs cancel) is decided
//! entirely by the `RequestCtl` CAS — whichever transition wins
//! determines both the wire answer and the bookkeeping, so no outcome
//! can be double-reported.

use std::collections::{BTreeMap, BinaryHeap, HashMap, VecDeque};
use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex, Weak};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::wire::{self, Frame, FrameReader, Status, WHOLE_REQUEST};
use crate::control::{FleetScheduler, Governor};
use crate::coordinator::{Coordinator, CtlState, InferResponse, Metrics, RequestCtl, StreamSink};
use crate::obs::{
    render_prometheus, render_trace, EventKind, MetricsHub, SloEngine, SloSpec, TraceRing,
};
use crate::util::{lock_recover, FaultPlan};

/// Per-session configuration.
#[derive(Debug, Clone)]
pub struct SessionCfg {
    /// Credit window: max admitted-and-unfinished requests. Frames past
    /// the limit are parked (when `park > 0` and the park queue has
    /// room) or rejected (`Status::Rejected`).
    pub max_inflight: usize,
    /// Park-queue capacity for window-overflow requests: instead of an
    /// immediate `Rejected`, up to this many overflow requests wait
    /// (FIFO) and are admitted as in-flight credit returns — so a
    /// well-behaved bursty client needs no client-side retry loop.
    /// `0` (the default) keeps the original reject-on-overflow
    /// behavior. A parked request's deadline clock keeps running from
    /// frame receipt: parked time counts against it.
    pub park: usize,
    /// Byte budget for the park queue: parked payloads are held
    /// **decoded** in memory, so a count cap alone lets one client pin
    /// `park × max-frame` bytes. When nonzero, a request whose decoded
    /// payload would push the queue's total past this budget is
    /// answered `Rejected` even if the count cap has room. `0` (the
    /// default) = no byte cap.
    pub park_bytes: usize,
    /// Deadline applied when a request carries none (`None` = requests
    /// without an explicit deadline never expire).
    pub default_deadline: Option<Duration>,
    /// Upper bound on the goodbye/shutdown drain: in-flight work still
    /// unfinished after this long is cancelled so the session thread
    /// always exits.
    pub drain_timeout: Duration,
    /// SO_SNDTIMEO on the session socket. A client that stops reading
    /// fills its TCP buffer; without this, a blocking reply write
    /// would pin whichever thread holds the writer mutex (a worker, or
    /// worse the shared reaper) forever. With it, the first stalled
    /// write errors, the session is marked dead, and every later write
    /// short-circuits — one slow client costs at most one timeout.
    pub write_timeout: Duration,
}

impl Default for SessionCfg {
    fn default() -> SessionCfg {
        SessionCfg {
            max_inflight: 64,
            park: 0,
            park_bytes: 0,
            default_deadline: None,
            drain_timeout: Duration::from_secs(10),
            write_timeout: Duration::from_secs(5),
        }
    }
}

// ---------------------------------------------------------------------------
// Timeout reaper

/// One registered deadline: fires `fire` at (or shortly after) `when`
/// unless the whole reaper shuts down first. The callback owns its own
/// idempotence (it CASes the request ctl and no-ops when it loses).
/// `alive` is the compaction key: once the request's ctl is gone or
/// terminal, the entry is dead weight and a sweep may drop it early.
struct Deadline {
    when: Instant,
    seq: u64,
    alive: Weak<RequestCtl>,
    fire: Box<dyn FnOnce() + Send>,
}

impl Deadline {
    /// Could firing still have an effect? (Only an `Active` ctl can
    /// lose the expire CAS to us.)
    fn still_matters(&self) -> bool {
        self.alive.upgrade().is_some_and(|c| c.state() == CtlState::Active)
    }
}

impl PartialEq for Deadline {
    fn eq(&self, other: &Self) -> bool {
        self.when == other.when && self.seq == other.seq
    }
}
impl Eq for Deadline {}
impl PartialOrd for Deadline {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Deadline {
    /// Reversed so `BinaryHeap` (a max-heap) pops the *earliest*
    /// deadline first.
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        other.when.cmp(&self.when).then(other.seq.cmp(&self.seq))
    }
}

/// Heap size that triggers the first compaction sweep.
const REAPER_COMPACT_MIN: usize = 1024;

#[derive(Default)]
struct ReaperState {
    heap: BinaryHeap<Deadline>,
    seq: u64,
    closed: bool,
    /// Next heap length at which to sweep dead entries (amortized
    /// O(1) per register; doubled after each sweep so a mostly-live
    /// heap is not rescanned on every push).
    next_compact: usize,
}

/// Shared monotonic timeout thread: every session registers its
/// requests' deadlines here, so deadline enforcement costs one parked
/// thread total — not one timer per request or per session.
pub struct Reaper {
    state: Arc<(Mutex<ReaperState>, Condvar)>,
    handle: Mutex<Option<JoinHandle<()>>>,
}

impl Default for Reaper {
    fn default() -> Self {
        Self::new()
    }
}

impl Reaper {
    /// Start the reaper thread.
    pub fn new() -> Reaper {
        let state: Arc<(Mutex<ReaperState>, Condvar)> = Arc::default();
        let thread_state = Arc::clone(&state);
        let handle = std::thread::spawn(move || reaper_loop(thread_state));
        Reaper { state, handle: Mutex::new(Some(handle)) }
    }

    /// Register `fire` to run at `when`, keyed to `ctl` for early
    /// reclamation: requests that complete or are cancelled long before
    /// their deadline leave dead heap entries, and a long-deadline
    /// high-rate server would otherwise hold every one until its
    /// wall-clock expiry. The callback must be cheap, capture the ctl
    /// weakly, and tolerate racing the request's other outcomes (CAS
    /// first).
    pub fn register(&self, when: Instant, ctl: &Arc<RequestCtl>, fire: Box<dyn FnOnce() + Send>) {
        let (lock, cv) = &*self.state;
        let mut st = lock.lock().unwrap();
        if st.closed {
            return; // shutting down: pending work is being cancelled anyway
        }
        let seq = st.seq;
        st.seq += 1;
        st.heap.push(Deadline { when, seq, alive: Arc::downgrade(ctl), fire });
        // Amortized sweep: drop entries whose requests already reached
        // a terminal state (their callbacks are guaranteed no-ops).
        if st.heap.len() >= st.next_compact.max(REAPER_COMPACT_MIN) {
            st.heap.retain(Deadline::still_matters);
            st.next_compact = (st.heap.len() * 2).max(REAPER_COMPACT_MIN);
        }
        cv.notify_one();
    }

    /// Deadlines currently pending (tests/observability).
    pub fn pending(&self) -> usize {
        self.state.0.lock().unwrap().heap.len()
    }

    /// Stop the timer thread. Unfired deadlines are dropped — callers
    /// shut the reaper down only after their sessions have drained.
    pub fn shutdown(&self) {
        {
            let (lock, cv) = &*self.state;
            lock.lock().unwrap().closed = true;
            cv.notify_all();
        }
        if let Some(h) = self.handle.lock().unwrap().take() {
            h.join().expect("reaper thread panicked");
        }
    }
}

/// Dropping without [`Reaper::shutdown`] must not leak a permanently
/// parked timer thread (shutdown is idempotent, so the explicit path
/// stays the graceful one).
impl Drop for Reaper {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn reaper_loop(state: Arc<(Mutex<ReaperState>, Condvar)>) {
    let (lock, cv) = &*state;
    let mut st = lock.lock().unwrap();
    loop {
        if st.closed {
            return;
        }
        let now = Instant::now();
        // Fire everything due, outside the lock (callbacks take session
        // locks and write sockets).
        if st.heap.peek().is_some_and(|d| d.when <= now) {
            let due = st.heap.pop().unwrap();
            drop(st);
            (due.fire)();
            st = lock.lock().unwrap();
            continue;
        }
        let wait = st.heap.peek().map(|d| d.when.saturating_duration_since(now));
        st = match wait {
            Some(w) => cv.wait_timeout(st, w).unwrap().0,
            None => cv.wait(st).unwrap(),
        };
    }
}

// ---------------------------------------------------------------------------
// Session

/// Why a session stopped reading (logs/tests).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SessionExit {
    /// Clean goodbye handshake (client- or server-initiated).
    Goodbye,
    /// Peer closed or the socket failed.
    Disconnect,
    /// The peer broke framing (bad magic/CRC/length).
    ProtocolError,
}

struct Inflight {
    ctl: Arc<RequestCtl>,
    /// Target model: the per-tenant inflight gauge must decrement the
    /// same row it incremented, whichever thread returns the credit.
    model: u32,
}

/// A validated window-overflow request waiting for in-flight credit.
struct Parked {
    id: u64,
    deadline_ms: u32,
    sample_len: usize,
    /// Validated target model (the coordinator id the request named).
    model: u32,
    data: wire::Payload,
    /// Frame receipt time — the deadline clock's origin, so time spent
    /// parked counts against the request's deadline.
    t_recv: Instant,
    /// Lifecycle control, created at receipt: the reaper's deadline
    /// entry is registered against it immediately, so a parked request
    /// whose deadline lapses gets its `Expired` frame promptly — not
    /// whenever a credit happens to return.
    ctl: Arc<RequestCtl>,
}

impl Parked {
    /// Decoded payload bytes this entry pins while parked (the byte
    /// budget's unit of account).
    fn byte_cost(&self) -> usize {
        self.data.byte_len()
    }
}

/// The park queue plus its running decoded-byte total: every mutation
/// goes through these methods so the byte gauge can never drift from
/// the queue contents.
#[derive(Default)]
struct ParkQueue {
    q: VecDeque<Parked>,
    /// Sum of `byte_cost` over `q`.
    bytes: usize,
}

impl ParkQueue {
    fn is_empty(&self) -> bool {
        self.q.is_empty()
    }

    fn len(&self) -> usize {
        self.q.len()
    }

    fn contains_id(&self, id: u64) -> bool {
        self.q.iter().any(|p| p.id == id)
    }

    fn push_back(&mut self, p: Parked) {
        self.bytes += p.byte_cost();
        self.q.push_back(p);
    }

    fn push_front(&mut self, p: Parked) {
        self.bytes += p.byte_cost();
        self.q.push_front(p);
    }

    fn pop_front(&mut self) -> Option<Parked> {
        let p = self.q.pop_front()?;
        self.bytes -= p.byte_cost();
        Some(p)
    }

    /// Remove the entry with `id`, if parked.
    fn remove_id(&mut self, id: u64) -> Option<Parked> {
        let i = self.q.iter().position(|p| p.id == id)?;
        let p = self.q.remove(i)?;
        self.bytes -= p.byte_cost();
        Some(p)
    }

    fn drain_all(&mut self) -> Vec<Parked> {
        self.bytes = 0;
        self.q.drain(..).collect()
    }
}

pub(crate) struct SessionShared {
    /// Write half (reads go through the session thread's own clone).
    /// A mutex serializes frames from N workers + the reaper + the
    /// session thread.
    writer: Mutex<TcpStream>,
    /// Socket failed or closed: suppress all further writes.
    dead: AtomicBool,
    /// No new admissions; drain and close.
    draining: AtomicBool,
    inflight: Mutex<HashMap<u64, Inflight>>,
    /// Status frames queued by the reaper's deadline callbacks. The
    /// reaper thread is shared by every session, so it must never
    /// block on one session's socket — it only CASes and enqueues
    /// here; the session's own thread flushes (and eats any
    /// write_timeout stall itself).
    deferred: Mutex<Vec<(u64, Status)>>,
    /// FIFO of validated window-overflow requests awaiting admission
    /// (bounded by `cfg.park` entries and `cfg.park_bytes` decoded
    /// bytes; empty forever when parking is off).
    park: Mutex<ParkQueue>,
    cfg: SessionCfg,
    coord: Arc<Coordinator>,
    /// Shared deadline timer (one thread server-wide); held here so
    /// credit-return admission can register parked requests' deadlines
    /// from whichever thread frees the credit.
    reaper: Arc<Reaper>,
    /// Adaptive control plane, when the server runs one: the
    /// `SetBudget`/`Stats` admin frames land here.
    governor: Option<Arc<Governor>>,
    /// Multi-model control plane; takes precedence over `governor` for
    /// the admin frames when both are configured (they never should
    /// be — the listener installs one or the other).
    scheduler: Option<Arc<FleetScheduler>>,
    /// Deterministic chaos plan, when the server runs one: injects
    /// reply delays and frame corruption on the write path and read
    /// stalls on the session thread (worker-side panics are injected
    /// by the coordinator's own copy of the plan).
    fault: Option<Arc<FaultPlan>>,
    /// Shared "session" flight-recorder ring (admission lifecycle
    /// events: Park, Admit); `None` when observability is off.
    ring: Option<Arc<TraceRing>>,
    /// Per-tenant SLO engine, when the server runs one: requests
    /// consult it at admission (a tripped tenant's overflow is
    /// answered `Throttled`), and the `SetSlo` admin frame lands here.
    slo: Option<Arc<SloEngine>>,
    metrics: Arc<Metrics>,
}

impl SessionShared {
    /// Write one frame; on failure mark the session dead (workers keep
    /// computing, their replies just stop going anywhere).
    fn send(&self, frame: &Frame) -> bool {
        if self.dead.load(Ordering::Acquire) {
            return false;
        }
        let mut bytes = wire::encode(frame);
        if let Some(f) = &self.fault {
            // Delay outside the writer lock so one injected stall
            // never serializes every other sender on this session.
            if let Some(d) = f.reply_delay() {
                std::thread::sleep(d);
            }
            // A corrupted frame fails the client's CRC check; the
            // retry client treats that as a dead connection.
            f.corrupt_frame(&mut bytes);
        }
        let mut w = lock_recover(&self.writer);
        match w.write_all(&bytes).and_then(|()| w.flush()) {
            Ok(()) => true,
            Err(_) => {
                self.dead.store(true, Ordering::Release);
                false
            }
        }
    }

    /// Remove `id` from the window and update the gauges (global and
    /// per-tenant). Only the winner of the ctl CAS calls this, so the
    /// accounting is exact.
    fn finish(&self, id: u64) {
        if let Some(inf) = lock_recover(&self.inflight).remove(&id) {
            self.metrics.inflight_delta(-1);
            self.metrics.tenant_inflight_delta(inf.model as usize, -1);
        }
    }

    fn status_reply(&self, id: u64, status: Status) {
        self.send(&Frame::Response {
            id,
            slot: WHOLE_REQUEST,
            status,
            predicted: 0,
            queue_us: 0,
            service_us: 0,
            mac_skipped: 0.0,
            logits: Vec::new(),
        });
    }
}

/// In-order streaming sink for one request: workers deposit sample
/// responses in completion order; the sink releases them to the wire
/// in slot order (parking gaps), suppresses everything once the
/// request's ctl leaves `Active`, and completes the request when the
/// last slot ships.
struct SessionSink {
    shared: Arc<SessionShared>,
    id: u64,
    ctl: Arc<RequestCtl>,
    /// Target model, so a worker-failure outcome can be charged to the
    /// right tenant's error counter.
    model: u32,
    n_samples: usize,
    order: Mutex<ReorderState>,
}

#[derive(Default)]
struct ReorderState {
    next_slot: usize,
    parked: BTreeMap<usize, InferResponse>,
}

impl StreamSink for SessionSink {
    fn put(&self, slot: usize, resp: InferResponse) {
        let mut ord = lock_recover(&self.order);
        ord.parked.insert(slot, resp);
        // Ship the contiguous prefix. The ctl check sits inside the
        // loop: a cancel that lands mid-batch stops the stream exactly
        // where it caught it.
        loop {
            let next = ord.next_slot;
            let Some(resp) = ord.parked.remove(&next) else { break };
            if self.ctl.is_dead() {
                ord.parked.clear();
                return;
            }
            let slot = next as u32;
            self.shared.send(&Frame::Response {
                id: self.id,
                slot,
                status: Status::Ok,
                predicted: resp.predicted.min(u16::MAX as usize) as u16,
                queue_us: resp.queue_us.min(u32::MAX as u64) as u32,
                service_us: resp.service_us.min(u32::MAX as u64) as u32,
                mac_skipped: resp.mac_skipped as f32,
                logits: resp.logits,
            });
            ord.next_slot += 1;
        }
        if ord.next_slot == self.n_samples {
            drop(ord);
            // Beat the reaper to the outcome: only the CAS winner does
            // the window bookkeeping.
            if self.ctl.complete() {
                self.shared.finish(self.id);
                // The freed credit may admit a parked request.
                try_admit_parked(&self.shared);
            }
        }
    }

    /// A worker panicked with this request's sample in flight. The
    /// supervisor already won the ctl CAS (`fail()`), so every
    /// still-queued sibling sample is a tombstone and no sub-reply can
    /// race this: report the terminal outcome once, return the window
    /// credit, and let the freed credit admit parked work. Runs on the
    /// supervisor's (worker) thread, which writes sockets like any
    /// other worker reply.
    fn fail(&self) {
        lock_recover(&self.order).parked.clear();
        self.shared.finish(self.id);
        self.shared.metrics.record_tenant_error(self.model as usize);
        self.shared.status_reply(self.id, Status::Failed);
        try_admit_parked(&self.shared);
    }
}

/// A running session: the reading thread plus its shared state.
pub struct SessionHandle {
    shared: Arc<SessionShared>,
    join: JoinHandle<SessionExit>,
}

impl SessionHandle {
    /// Ask the session to drain: no new admissions, finish in-flight,
    /// goodbye, exit. Idempotent; used by listener shutdown.
    pub fn begin_drain(&self) {
        self.shared.draining.store(true, Ordering::Release);
    }

    /// Whether the session thread has exited.
    pub fn is_finished(&self) -> bool {
        self.join.is_finished()
    }

    /// Join the session thread (after [`SessionHandle::begin_drain`]).
    pub fn join(self) -> SessionExit {
        self.join.join().expect("session thread panicked")
    }
}

/// Spawn the session thread for one accepted connection.
pub(crate) fn spawn_session(
    stream: TcpStream,
    coord: Arc<Coordinator>,
    reaper: Arc<Reaper>,
    cfg: SessionCfg,
    governor: Option<Arc<Governor>>,
    scheduler: Option<Arc<FleetScheduler>>,
    fault: Option<Arc<FaultPlan>>,
    slo: Option<Arc<SloEngine>>,
) -> std::io::Result<SessionHandle> {
    let read_half = stream.try_clone()?;
    // Period between liveness checks of the draining/dead flags while
    // blocked on a quiet socket.
    read_half.set_read_timeout(Some(Duration::from_millis(50)))?;
    stream.set_write_timeout(Some(cfg.write_timeout))?;
    let _ = stream.set_nodelay(true);
    let metrics = Arc::clone(&coord.metrics);
    let ring = coord.recorder().map(|r| r.ring("session"));
    let shared = Arc::new(SessionShared {
        writer: Mutex::new(stream),
        dead: AtomicBool::new(false),
        draining: AtomicBool::new(false),
        inflight: Mutex::new(HashMap::new()),
        deferred: Mutex::new(Vec::new()),
        park: Mutex::new(ParkQueue::default()),
        cfg,
        coord,
        reaper,
        governor,
        scheduler,
        fault,
        ring,
        slo,
        metrics,
    });
    let thread_shared = Arc::clone(&shared);
    let join = std::thread::spawn(move || session_loop(thread_shared, read_half));
    Ok(SessionHandle { shared, join })
}

fn session_loop(shared: Arc<SessionShared>, mut read_half: TcpStream) -> SessionExit {
    shared.metrics.session_opened();
    let mut reader = FrameReader::new();
    let mut buf = vec![0u8; 64 * 1024];
    let mut drain_started: Option<Instant> = None;
    let exit = loop {
        // Flush status frames the reaper deferred to us (the read
        // timeout bounds the added notification latency to ~50 ms —
        // the deadline itself has already passed).
        flush_deferred(&shared);
        // Drain bookkeeping: once draining, leave as soon as the window
        // empties (or the timeout forces the issue).
        if shared.draining.load(Ordering::Acquire) {
            let t0 = *drain_started.get_or_insert_with(Instant::now);
            let empty = lock_recover(&shared.inflight).is_empty();
            if empty || t0.elapsed() > shared.cfg.drain_timeout {
                if !empty {
                    cancel_all(&shared);
                }
                // Parked overflow is never admitted during a drain:
                // answer it Rejected (graceful-shutdown backpressure)
                // before saying goodbye.
                reject_parked(&shared);
                // An expiry may have ended the drain after the flush at
                // the top of this iteration; the reaper queues the
                // Expired frame before emptying the window, so flushing
                // again here provably ships it before the goodbye.
                flush_deferred(&shared);
                shared.send(&Frame::Goodbye);
                break SessionExit::Goodbye;
            }
        }
        if shared.dead.load(Ordering::Acquire) {
            break SessionExit::Disconnect;
        }
        match read_half.read(&mut buf) {
            Ok(0) => break SessionExit::Disconnect,
            Ok(n) => {
                // Injected read stall: the peer's bytes sit unparsed
                // for a bounded moment, exercising deadline expiry and
                // client-side timeouts under a slow server.
                if let Some(d) = shared.fault.as_ref().and_then(|f| f.read_stall()) {
                    std::thread::sleep(d);
                }
                reader.feed(&buf[..n]);
                loop {
                    match reader.next() {
                        Ok(Some(frame)) => {
                            if !handle_frame(&shared, frame) {
                                // Goodbye received: switch to draining;
                                // keep reading so cancels still land.
                                shared.draining.store(true, Ordering::Release);
                            }
                        }
                        Ok(None) => break,
                        Err(wire::WireError::BadVersion(v)) => {
                            // A well-framed peer speaking a protocol
                            // version we don't: refuse it cleanly — a
                            // Goodbye and an orderly close — so its
                            // fallback logic sees a negotiation
                            // failure, not line noise.
                            eprintln!(
                                "[serve] unsupported wire version {v}, closing session"
                            );
                            shared.send(&Frame::Goodbye);
                            return finish_session(&shared, SessionExit::Goodbye);
                        }
                        Err(e) => {
                            // Unframed stream: nothing after this point
                            // can be trusted. Hang up; finish_session
                            // cancels whatever was in flight.
                            eprintln!("[serve] protocol error, closing session: {e}");
                            shared.send(&Frame::Goodbye);
                            return finish_session(&shared, SessionExit::ProtocolError);
                        }
                    }
                }
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                continue;
            }
            Err(_) => break SessionExit::Disconnect,
        }
    };
    finish_session(&shared, exit)
}

fn finish_session(shared: &Arc<SessionShared>, exit: SessionExit) -> SessionExit {
    // Whatever is still in flight dies with the connection: suppress
    // replies, tombstone queued samples. Parked overflow is answered
    // Rejected (a no-op write if the socket is already gone).
    reject_parked(shared);
    cancel_all(shared);
    shared.dead.store(true, Ordering::Release);
    shared.metrics.session_closed();
    exit
}

/// Reject every parked request (drain/disconnect: parked work is never
/// admitted once the session stops accepting). Session-thread only —
/// it writes the socket.
fn reject_parked(shared: &Arc<SessionShared>) {
    let drained: Vec<Parked> = lock_recover(&shared.park).drain_all();
    for p in drained {
        shared.metrics.record_rejected();
        shared.status_reply(p.id, Status::Rejected);
    }
}

/// Write out status frames the reaper deferred to this session.
fn flush_deferred(shared: &Arc<SessionShared>) {
    let deferred: Vec<(u64, Status)> =
        std::mem::take(&mut *lock_recover(&shared.deferred));
    for (id, status) in deferred {
        shared.status_reply(id, status);
    }
}

/// Cancel every in-flight request (disconnect / drain timeout path).
fn cancel_all(shared: &Arc<SessionShared>) {
    let drained: Vec<(u64, Inflight)> =
        lock_recover(&shared.inflight).drain().collect();
    for (_, inf) in &drained {
        inf.ctl.cancel();
        shared.metrics.inflight_delta(-1);
        shared.metrics.tenant_inflight_delta(inf.model as usize, -1);
    }
}

/// Process one frame; returns `false` when the frame was a client
/// `Goodbye` (the caller switches the session into draining).
fn handle_frame(shared: &Arc<SessionShared>, frame: Frame) -> bool {
    match frame {
        Frame::Request { id, deadline_ms, sample_len, model, data } => {
            handle_request(shared, id, deadline_ms, sample_len, model, data);
            true
        }
        Frame::Cancel { id } => {
            // Silence is the contract: sub-replies just stop. Only the
            // CAS winner books the cancel (a cancel racing completion
            // or expiry is a no-op).
            let ctl = lock_recover(&shared.inflight).get(&id).map(|inf| Arc::clone(&inf.ctl));
            if let Some(ctl) = ctl {
                if ctl.cancel() {
                    shared.finish(id);
                    shared.metrics.record_cancelled();
                    // The cancel returned a credit: a parked request
                    // may now be admissible.
                    try_admit_parked(shared);
                }
            } else {
                // Cancelling a still-parked request drops it silently
                // (same contract as cancelling queued work); the CAS
                // keeps a racing expiry from double-reporting.
                let parked_ctl =
                    lock_recover(&shared.park).remove_id(id).map(|p| p.ctl);
                if let Some(ctl) = parked_ctl {
                    if ctl.cancel() {
                        shared.metrics.record_cancelled();
                    }
                }
            }
            true
        }
        Frame::Ping { id } => {
            shared.send(&Frame::Pong { id });
            true
        }
        // Admin pair: adjust an energy budget (positive values) or
        // just query; always answered with a Stats frame. Without any
        // control plane the reply carries `scale_q8 == 0` — "adaptive
        // control disabled" — instead of an error, so probes are cheap.
        Frame::SetBudget { id, budget_mj, model } => {
            shared.send(&handle_set_budget(shared, id, budget_mj, model));
            true
        }
        // Observability admin pair (v5): answer with the filled body.
        // Rendering walks shared counters and lock-free ring snapshots
        // only, so a scrape never blocks the serving path.
        Frame::Scrape { id, .. } => {
            // Refresh the point-in-time shard gauges so the scrape
            // reflects current queue imbalance, not the last report.
            shared.coord.publish_shard_costs();
            let body = render_prometheus(&metrics_hub(shared));
            shared.send(&Frame::Scrape { id, body });
            true
        }
        Frame::TraceDump { id, .. } => {
            let body = render_trace(&metrics_hub(shared));
            shared.send(&Frame::TraceDump { id, body });
            true
        }
        // SLO admin (v6): declare or replace one tenant's objectives at
        // runtime. Always answered with a Stats frame echoing the id
        // (the SetBudget idiom) so a client can fire-and-confirm; with
        // no SLO engine configured the frame degrades to a stats query
        // — probes stay cheap, never an error.
        Frame::SetSlo { id, model, p99_ms, keep_floor, err_ceiling } => {
            if let Some(slo) = &shared.slo {
                // Unknown tenant: rejected silently (same contract as
                // SetBudget with an unknown model id).
                let _ = slo.set_slo(
                    model,
                    SloSpec {
                        p99_ms,
                        keep_floor: keep_floor as f64,
                        err_ceiling: err_ceiling as f64,
                    },
                );
            }
            shared.send(&handle_set_budget(shared, id, 0.0, model));
            true
        }
        Frame::Goodbye => false,
        // Server-only frames arriving from a client are ignored (they
        // framed correctly; dropping them is safer than hanging up).
        Frame::Response { .. } | Frame::Pong { .. } | Frame::Stats { .. } => true,
    }
}

/// Assemble the exposition hub for one admin scrape: every piece is a
/// cheap `Arc` clone of state the session already holds.
fn metrics_hub(shared: &Arc<SessionShared>) -> MetricsHub {
    let model_names = (0..shared.coord.model_count())
        .map(|i| shared.coord.model_name(i as u32).unwrap_or_default().to_string())
        .collect();
    MetricsHub {
        metrics: Arc::clone(&shared.metrics),
        governor: shared.governor.clone(),
        scheduler: shared.scheduler.clone(),
        recorder: shared.coord.recorder(),
        slo: shared.slo.clone(),
        model_names,
        kernel_backend: crate::engine::KernelBackend::active_label(),
    }
}

/// Build the `Stats` reply to one `SetBudget` admin frame, applying
/// the budget change first when `budget_mj > 0`.
///
/// Routing: with a [`FleetScheduler`], [`wire::FLEET_MODEL`] scope
/// re-budgets the whole fleet and a model id caps that tenant (the
/// reply then reports that tenant; fleet scope reports model 0, the
/// convention a single-model v3 client already expects). With a
/// [`Governor`], only fleet scope or model 0 applies the change —
/// there is exactly one budget to move. The self-healing gauges
/// (`worker_panics`, `respawns`) ride every reply: panic containment
/// is a coordinator property, not a control-plane one.
fn handle_set_budget(
    shared: &Arc<SessionShared>,
    id: u64,
    budget_mj: f64,
    model: u32,
) -> Frame {
    let m = shared.metrics.snapshot();
    // Common "no control / unknown tenant" shape; the caller fills in
    // whatever fleet shape it does know.
    let disabled = |model: u32, models_loaded: u32, fleet_budget_mj: f64| Frame::Stats {
        id,
        scale_q8: 0,
        step: 0,
        steps_total: 0,
        budget_mj: 0.0,
        ewma_mj: 0.0,
        keep_ratio: 0.0,
        cache_hits: 0,
        cache_misses: 0,
        swaps: 0,
        bg_pending: 0,
        bg_compiled: 0,
        bg_upgrades: 0,
        worker_panics: m.worker_panics,
        respawns: m.respawns,
        drift_trips: 0,
        recalibrations: 0,
        model,
        models_loaded,
        fleet_budget_mj,
    };
    if let Some(sched) = &shared.scheduler {
        if budget_mj > 0.0 {
            if model == wire::FLEET_MODEL {
                sched.set_fleet_budget(budget_mj);
            } else {
                // Unknown tenant: rejected silently here, visible in
                // the reply (scale_q8 == 0 for that model id).
                let _ = sched.set_tenant_cap(model, Some(budget_mj));
            }
        }
        let fleet = sched.fleet_status();
        let stat_model = if model == wire::FLEET_MODEL { 0 } else { model };
        return match sched.status(stat_model) {
            Some(s) => Frame::Stats {
                id,
                scale_q8: s.scale_q8,
                step: s.step as u32,
                steps_total: s.steps_total as u32,
                // Fleet scope reports the fleet budget; model scope
                // that tenant's cap (0 = uncapped).
                budget_mj: if model == wire::FLEET_MODEL {
                    fleet.fleet_budget_mj
                } else {
                    s.cap_mj.unwrap_or(0.0)
                },
                ewma_mj: s.ewma_mj,
                keep_ratio: s.keep_ratio as f32,
                cache_hits: s.cache_hits,
                cache_misses: s.cache_misses,
                swaps: s.swaps,
                // The scheduler compiles on its solve thread, not a
                // background compile pipeline: the bg_* gauges are
                // governor-specific and read 0 here.
                bg_pending: 0,
                bg_compiled: 0,
                bg_upgrades: 0,
                worker_panics: m.worker_panics,
                respawns: m.respawns,
                drift_trips: s.drift_trips,
                recalibrations: s.recalibrations,
                model: stat_model,
                models_loaded: fleet.models as u32,
                fleet_budget_mj: fleet.fleet_budget_mj,
            },
            None => disabled(stat_model, fleet.models as u32, fleet.fleet_budget_mj),
        };
    }
    let models_loaded = shared.coord.model_count() as u32;
    match &shared.governor {
        Some(g) => {
            if budget_mj > 0.0 && (model == wire::FLEET_MODEL || model == 0) {
                g.set_budget(budget_mj);
            }
            let s = g.status();
            Frame::Stats {
                id,
                scale_q8: s.scale_q8,
                step: s.step as u32,
                steps_total: s.steps_total as u32,
                budget_mj: s.budget_mj,
                ewma_mj: s.ewma_mj,
                keep_ratio: s.keep_ratio as f32,
                cache_hits: s.cache_hits,
                cache_misses: s.cache_misses,
                swaps: s.swaps,
                bg_pending: s.bg_pending,
                bg_compiled: s.bg_compiled,
                bg_upgrades: s.bg_upgrades,
                worker_panics: m.worker_panics,
                respawns: m.respawns,
                drift_trips: s.drift_trips,
                recalibrations: s.recalibrations,
                model: 0,
                models_loaded,
                fleet_budget_mj: 0.0,
            }
        }
        None => disabled(0, models_loaded, 0.0),
    }
}

fn handle_request(
    shared: &Arc<SessionShared>,
    id: u64,
    deadline_ms: u32,
    sample_len: u32,
    model: u32,
    data: wire::Payload,
) {
    if shared.draining.load(Ordering::Acquire) {
        // Graceful-shutdown refusal is backpressure ("retry elsewhere"),
        // not a server failure.
        shared.metrics.record_rejected();
        shared.status_reply(id, Status::Rejected);
        return;
    }
    // Structural validation.
    let sample_len = sample_len as usize;
    if sample_len == 0 || data.is_empty() || data.len() % sample_len != 0 {
        shared.status_reply(id, Status::Error);
        return;
    }
    // Model validation: the id must name a hosted model, and the
    // sample length must match THAT model's input — checked here so an
    // unknown tenant is a structured refusal, never queued work.
    let Some(expect) = shared.coord.input_len_of(model) else {
        shared.status_reply(id, Status::Error);
        return;
    };
    if expect != sample_len {
        shared.metrics.record_tenant_error(model as usize);
        shared.status_reply(id, Status::Error);
        return;
    }
    // Per-tenant SLO admission: free when the tenant's burn rate is
    // within its objectives; once tripped, the engine's token bucket /
    // inflight quota decides, and overflow is answered `Throttled` — a
    // tenant-scoped retry-later, distinct from the session-scoped
    // `Rejected` backpressure below.
    if let Some(slo) = &shared.slo {
        if !slo.try_admit(model) {
            shared.metrics.record_tenant_throttled(model as usize);
            shared.status_reply(id, Status::Throttled);
            return;
        }
    }
    // Unique id across both the window and the park queue (a parked
    // duplicate would otherwise collide with itself at admission).
    {
        let dup_window = lock_recover(&shared.inflight).contains_key(&id);
        let dup_park = lock_recover(&shared.park).contains_id(id);
        if dup_window || dup_park {
            shared.status_reply(id, Status::Error);
            return;
        }
    }

    let ctl = RequestCtl::shared();
    let t_recv = Instant::now();
    let parked = Parked {
        id,
        deadline_ms,
        sample_len,
        model,
        data,
        t_recv,
        ctl: Arc::clone(&ctl),
    };
    // One park-lock hold covers the whole decide-then-park sequence
    // (lock order park → window, same as try_admit_parked), so a
    // credit returning concurrently either sees the queue before this
    // frame or after it — the frame can neither strand unparked nor
    // jump an older parked request (FIFO fairness: a new arrival lines
    // up behind existing overflow instead of racing a freed credit
    // past it).
    let outcome = {
        let mut park = lock_recover(&shared.park);
        if shared.cfg.park > 0 && !park.is_empty() {
            park_or_reject(shared, &mut park, parked)
        } else {
            match admit_and_submit(shared, parked) {
                Admit::Full(p) => park_or_reject(shared, &mut park, p),
                other => other,
            }
        }
    };
    match outcome {
        Admit::Ok => {
            if let Some(d) = request_deadline(shared, deadline_ms) {
                register_expiry(shared, id, &ctl, t_recv + d);
            }
        }
        Admit::Parked => {
            shared.metrics.record_parked();
            if let Some(r) = &shared.ring {
                r.emit(EventKind::Park, id, 0, 0, 0);
            }
            // Registered at receipt, even while parked: the Expired
            // frame is due at the deadline, not at the next credit
            // return.
            if let Some(d) = request_deadline(shared, deadline_ms) {
                register_expiry(shared, id, &ctl, t_recv + d);
            }
        }
        Admit::Full(p) => {
            // Unreachable (park_or_reject consumes Full), kept total.
            shared.metrics.record_rejected();
            shared.status_reply(p.id, Status::Rejected);
        }
        Admit::Rejected(id) => {
            shared.metrics.record_rejected();
            shared.status_reply(id, Status::Rejected);
        }
        Admit::Dup(id) => shared.status_reply(id, Status::Error),
    }
}

/// Outcome of one admission attempt.
enum Admit {
    /// Admitted and submitted (or consumed as already dead/lapsed).
    Ok,
    /// Window full: the request is handed back untouched.
    Full(Parked),
    /// Parked for credit-return admission.
    Parked,
    /// Park queue full too: reject (carries the id for the reply).
    Rejected(u64),
    /// The window already holds this id (carries it for the error
    /// reply).
    Dup(u64),
}

/// Park `p` if the queue has room under BOTH caps — entry count and
/// decoded-byte budget (caller holds the park lock) — else report
/// rejection.
fn park_or_reject(
    shared: &Arc<SessionShared>,
    park: &mut ParkQueue,
    p: Parked,
) -> Admit {
    let fits_count = park.len() < shared.cfg.park;
    let fits_bytes =
        shared.cfg.park_bytes == 0 || park.bytes + p.byte_cost() <= shared.cfg.park_bytes;
    if fits_count && fits_bytes {
        park.push_back(p);
        Admit::Parked
    } else {
        Admit::Rejected(p.id)
    }
}

/// Effective deadline of a request: explicit beats the session
/// default; 0 = none. The clock runs from frame receipt, so time
/// spent parked counts.
fn request_deadline(shared: &SessionShared, deadline_ms: u32) -> Option<Duration> {
    if deadline_ms > 0 {
        Some(Duration::from_millis(deadline_ms as u64))
    } else {
        shared.cfg.default_deadline
    }
}

/// Register a request's expiry with the shared reaper. The callback
/// handles the request wherever it sits at fire time: a parked entry
/// is removed from the queue, an admitted one has its credit
/// reclaimed and its queued samples tombstoned — either way exactly
/// one `Expired` frame is deferred to the session thread.
fn register_expiry(shared: &Arc<SessionShared>, id: u64, ctl: &Arc<RequestCtl>, when: Instant) {
    let weak: Weak<SessionShared> = Arc::downgrade(shared);
    // Weak captures only: a completed request must be reclaimable
    // (heap compaction) before its deadline arrives.
    let weak_ctl = Arc::downgrade(ctl);
    shared.reaper.register(
        when,
        ctl,
        Box::new(move || {
            let Some(ctl) = weak_ctl.upgrade() else { return };
            // Loser of the race against completion/cancel: usually
            // a no-op — but if the request died somewhere that
            // could not reach the session's window bookkeeping
            // (e.g. an executor-side defensive drop), reclaim the
            // credit here so it does not leak until disconnect.
            if !ctl.expire() {
                if ctl.is_dead() {
                    if let Some(shared) = weak.upgrade() {
                        shared.finish(id);
                        try_admit_parked(&shared);
                    }
                }
                return;
            }
            if let Some(shared) = weak.upgrade() {
                shared.metrics.record_expired();
                // Never write the socket from the shared reaper
                // thread: defer the frame to this session's thread.
                // Queue BEFORE finish(id): the drain path exits once
                // the window is empty, and this order guarantees the
                // frame is already queued by then, so its final
                // flush cannot miss it.
                lock_recover(&shared.deferred).push((id, Status::Expired));
                // Wherever the request sits: drop it from the park
                // queue (not yet admitted) and/or return its window
                // credit.
                lock_recover(&shared.park).remove_id(id);
                shared.finish(id);
                // Expiry returns a credit too.
                try_admit_parked(&shared);
            }
        }),
    );
}

/// Admit one validated request into the in-flight window and submit
/// it: the shared tail of the direct path and credit-return admission.
/// Callable from any thread — failures are reported through the
/// session's deferred status queue, never by writing the socket here.
fn admit_and_submit(shared: &Arc<SessionShared>, p: Parked) -> Admit {
    // Expired (or cancelled) while parked: the CAS winner already did
    // the bookkeeping; just consume the entry.
    if p.ctl.is_dead() {
        return Admit::Ok;
    }
    // Deterministic lapse check: the reaper may not have fired yet for
    // a deadline that passed in the park queue — racing a worker
    // against it over already-dead work could serve a request past its
    // deadline.
    if let Some(d) = request_deadline(shared, p.deadline_ms) {
        if p.t_recv.elapsed() >= d {
            if p.ctl.expire() {
                shared.metrics.record_expired();
                lock_recover(&shared.deferred).push((p.id, Status::Expired));
            }
            return Admit::Ok;
        }
    }
    {
        // Credit window + unique id, decided under the window lock so
        // concurrent admissions cannot both squeeze in.
        let mut window = lock_recover(&shared.inflight);
        if window.len() >= shared.cfg.max_inflight {
            return Admit::Full(p);
        }
        if window.contains_key(&p.id) {
            return Admit::Dup(p.id);
        }
        window.insert(p.id, Inflight { ctl: Arc::clone(&p.ctl), model: p.model });
    }
    shared.metrics.inflight_delta(1);
    shared.metrics.tenant_inflight_delta(p.model as usize, 1);
    if let Some(r) = &shared.ring {
        r.emit(EventKind::Admit, p.id, 0, 0, 0);
    }
    let Parked { id, sample_len, model, data, ctl, .. } = p;

    let flat = data.into_f32();
    let n_samples = flat.len() / sample_len;
    let xs: Vec<Vec<f32>> = flat.chunks_exact(sample_len).map(|c| c.to_vec()).collect();
    let sink = Arc::new(SessionSink {
        shared: Arc::clone(shared),
        id,
        ctl: Arc::clone(&ctl),
        model,
        n_samples,
        order: Mutex::new(ReorderState::default()),
    });
    if shared.coord.submit_streamed(id, model, xs, ctl, sink).is_err() {
        // Pool closed under us (server shutting down) or the model
        // table shifted: the ctl is already tombstoned by
        // submit_streamed. Deferred rather than written here — this
        // path can run on the reaper thread.
        shared.finish(id);
        shared.metrics.record_tenant_error(model as usize);
        lock_recover(&shared.deferred).push((id, Status::Error));
    }
    Admit::Ok
}

/// Admit parked requests while in-flight credit is available. Called
/// whenever a credit returns (completion, cancel, expiry). Any thread;
/// never writes the socket.
///
/// The park lock is held across each admission attempt so concurrent
/// credit returns admit in strict FIFO order. Lock order is
/// park → window (via `admit_and_submit`); no other path nests these
/// two, so the ordering is acyclic.
fn try_admit_parked(shared: &Arc<SessionShared>) {
    if shared.cfg.park == 0 {
        return;
    }
    loop {
        // No admissions during a drain: the session thread answers the
        // remaining parked frames `Rejected` on its way out.
        if shared.draining.load(Ordering::Acquire) {
            return;
        }
        let mut park = lock_recover(&shared.park);
        let Some(p) = park.pop_front() else { return };
        match admit_and_submit(shared, p) {
            Admit::Ok => continue, // more credit may be free
            Admit::Full(p) => {
                // Lost the race for the credit: back to the front so
                // FIFO order is preserved.
                park.push_front(p);
                return;
            }
            Admit::Dup(id) => {
                lock_recover(&shared.deferred).push((id, Status::Error));
                continue;
            }
            // admit_and_submit never parks or rejects.
            Admit::Parked | Admit::Rejected(_) => unreachable!(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn reaper_fires_in_deadline_order() {
        let reaper = Reaper::new();
        let log: Arc<Mutex<Vec<u32>>> = Arc::default();
        let ctl = RequestCtl::shared();
        let now = Instant::now();
        for (tag, ms) in [(2u32, 60u64), (1, 30), (3, 90)] {
            let log = Arc::clone(&log);
            reaper.register(
                now + Duration::from_millis(ms),
                &ctl,
                Box::new(move || {
                    log.lock().unwrap().push(tag);
                }),
            );
        }
        std::thread::sleep(Duration::from_millis(250));
        assert_eq!(*log.lock().unwrap(), vec![1, 2, 3]);
        assert_eq!(reaper.pending(), 0);
        reaper.shutdown();
    }

    #[test]
    fn reaper_shutdown_drops_unfired() {
        let reaper = Reaper::new();
        let fired = Arc::new(AtomicUsize::new(0));
        let ctl = RequestCtl::shared();
        let f = Arc::clone(&fired);
        reaper.register(
            Instant::now() + Duration::from_secs(3600),
            &ctl,
            Box::new(move || {
                f.fetch_add(1, Ordering::SeqCst);
            }),
        );
        reaper.shutdown();
        assert_eq!(fired.load(Ordering::SeqCst), 0);
        // register after shutdown is a no-op, not a panic
        reaper.register(Instant::now(), &ctl, Box::new(|| {}));
    }

    #[test]
    fn reaper_handles_already_due_deadlines() {
        let reaper = Reaper::new();
        let fired = Arc::new(AtomicUsize::new(0));
        let ctl = RequestCtl::shared();
        let f = Arc::clone(&fired);
        reaper.register(
            Instant::now() - Duration::from_millis(5),
            &ctl,
            Box::new(move || {
                f.fetch_add(1, Ordering::SeqCst);
            }),
        );
        std::thread::sleep(Duration::from_millis(100));
        assert_eq!(fired.load(Ordering::SeqCst), 1);
        reaper.shutdown();
    }

    #[test]
    fn reaper_compacts_dead_entries_before_their_deadline() {
        let reaper = Reaper::new();
        let far = Instant::now() + Duration::from_secs(3600);
        // Entries whose requests are already gone (ctl dropped) must
        // not pile up until their wall-clock expiry.
        for _ in 0..(3 * REAPER_COMPACT_MIN) {
            let ctl = RequestCtl::shared();
            reaper.register(far, &ctl, Box::new(|| {}));
            drop(ctl);
        }
        assert!(
            reaper.pending() <= REAPER_COMPACT_MIN + 1,
            "dead deadlines not compacted: {} pending",
            reaper.pending()
        );
        // A live Active entry survives sweeps.
        let live = RequestCtl::shared();
        reaper.register(far, &live, Box::new(|| {}));
        for _ in 0..(3 * REAPER_COMPACT_MIN) {
            let ctl = RequestCtl::shared();
            reaper.register(far, &ctl, Box::new(|| {}));
            drop(ctl);
        }
        assert!(reaper.pending() >= 1);
        reaper.shutdown();
        drop(live);
    }
}
