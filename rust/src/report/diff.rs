//! `BENCH_perf.json` trajectory comparator: the CI perf gate.
//!
//! [`super::bench::BenchPerf`] snapshots are write-only without a
//! reader; this module closes the loop. [`load_snapshot`] parses a
//! snapshot back (via a ~100-line recursive-descent JSON reader — no
//! serde in the vendored set), [`diff_snapshots`] matches rows between
//! two snapshots and computes deltas, and the `unit bench diff`
//! subcommand exits non-zero when a gated row regresses beyond the
//! tolerance — which is what lets CI refuse hot-path regressions.
//!
//! Gating policy (cross-machine reality): absolute throughputs
//! (inferences/s, req/s, samples/s) are only comparable on the same
//! machine, so they are gated in the default mode — the right mode for
//! "did my change slow the hot path on *this* box". The
//! `planned_speedup` ratios (planned vs naive on the *same* run) are
//! machine-portable, so `ratios_only` gates just those — the right
//! mode for CI runners whose absolute speed varies. Latency
//! percentiles and division ns/op are always informational.

use std::path::Path;

use super::bench::{BenchPerf, CompileRow, CoordRow, DivRow, EngineRow, EvalRow, LayerRow};

// ---------------------------------------------------------------- JSON

/// Minimal JSON value (everything `BENCH_perf.json` needs).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// JSON `null`.
    Null,
    /// Boolean.
    Bool(bool),
    /// Number (all numerics are `f64`).
    Num(f64),
    /// String.
    Str(String),
    /// Array.
    Arr(Vec<Json>),
    /// Object (ordered key→value pairs).
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Object field lookup (`None` on non-objects).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// The number, if this is `Num`.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// The string, if this is `Str`.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The items, if this is `Arr` (empty otherwise).
    pub fn as_arr(&self) -> &[Json] {
        match self {
            Json::Arr(items) => items,
            _ => &[],
        }
    }

    /// Field lookup with a numeric default (absent or `null` → default).
    fn num_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(Json::as_f64).unwrap_or(default)
    }
}

struct Parser<'a> {
    s: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, what: &str) -> String {
        format!("JSON parse error at byte {}: {what}", self.i)
    }

    fn skip_ws(&mut self) {
        while self.i < self.s.len() && self.s[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&mut self) -> Option<u8> {
        self.skip_ws();
        self.s.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'n' => self.literal("null", Json::Null),
            _ => self.number(),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.s[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while self
            .s
            .get(self.i)
            .is_some_and(|&c| c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E'))
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.s[start..self.i]).map_err(|_| self.err("utf8"))?;
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.s.get(self.i).copied().ok_or_else(|| self.err("unterminated string"))? {
                b'"' => {
                    self.i += 1;
                    return Ok(out);
                }
                b'\\' => {
                    self.i += 1;
                    let esc =
                        self.s.get(self.i).copied().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        // The emitter never writes \b \f \uXXXX; accept
                        // them leniently as a literal to stay total.
                        other => out.push(other as char),
                    }
                }
                _ => {
                    // Plain byte: the emitter writes ASCII; pass UTF-8
                    // through byte-wise via the original slice.
                    let start = self.i;
                    while self
                        .s
                        .get(self.i)
                        .is_some_and(|&c| c != b'"' && c != b'\\')
                    {
                        self.i += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.s[start..self.i])
                            .map_err(|_| self.err("utf8"))?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.eat(b'{')?;
        let mut fields = Vec::new();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.eat(b':')?;
            fields.push((key, self.value()?));
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(fields));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

/// Parse a JSON document.
pub fn parse_json(s: &str) -> Result<Json, String> {
    let mut p = Parser { s: s.as_bytes(), i: 0 };
    let v = p.value()?;
    p.skip_ws();
    if p.i != p.s.len() {
        return Err(p.err("trailing garbage"));
    }
    Ok(v)
}

// ------------------------------------------------------- snapshot load

/// Rebuild a [`BenchPerf`] from its JSON form. Sections absent in
/// older snapshots parse as empty — the diff then simply has fewer
/// matched rows, so baselines from earlier PRs keep working.
pub fn snapshot_from_json(text: &str) -> Result<BenchPerf, String> {
    let v = parse_json(text)?;
    let mut out = BenchPerf {
        model: v.get("model").and_then(Json::as_str).unwrap_or("").to_string(),
        ..Default::default()
    };
    for row in v.get("engine_throughput").map(Json::as_arr).unwrap_or(&[]) {
        out.engine.push(EngineRow {
            mode: row.get("mode").and_then(Json::as_str).unwrap_or("").into(),
            backend: row.get("backend").and_then(Json::as_str).unwrap_or("").into(),
            inf_per_s: row.num_or("inferences_per_s", 0.0),
            mconn_per_s: row.num_or("mconn_per_s", 0.0),
            us_per_inf: row.num_or("us_per_inference", 0.0),
        });
    }
    if let Some(Json::Obj(fields)) = v.get("planned_speedup") {
        for (mode, val) in fields {
            out.speedups.push((mode.clone(), val.as_f64().unwrap_or(0.0)));
        }
    }
    if let Some(Json::Obj(fields)) = v.get("division_ns_per_op") {
        for (name, val) in fields {
            out.divs.push(DivRow { name: name.clone(), ns_per_op: val.as_f64().unwrap_or(0.0) });
        }
    }
    for row in v.get("coordinator").map(Json::as_arr).unwrap_or(&[]) {
        out.coord.push(CoordRow {
            workers: row.num_or("workers", 0.0) as usize,
            req_per_s: row.num_or("req_per_s", 0.0),
            p50_us: row.num_or("p50_us", 0.0) as u64,
            p99_us: row.num_or("p99_us", 0.0) as u64,
            queue_p50_us: row.num_or("queue_p50_us", 0.0) as u64,
            queue_p99_us: row.num_or("queue_p99_us", 0.0) as u64,
            service_p50_us: row.num_or("service_p50_us", 0.0) as u64,
            service_p99_us: row.num_or("service_p99_us", 0.0) as u64,
        });
    }
    for row in v.get("batched_eval").map(Json::as_arr).unwrap_or(&[]) {
        out.eval.push(EvalRow {
            label: row.get("label").and_then(Json::as_str).unwrap_or("").into(),
            samples_per_s: row.num_or("samples_per_s", 0.0),
        });
    }
    for row in v.get("plan_compile_us").map(Json::as_arr).unwrap_or(&[]) {
        out.compile.push(CompileRow {
            label: row.get("label").and_then(Json::as_str).unwrap_or("").into(),
            us: row.num_or("us", 0.0),
        });
    }
    // Informational only (never diffed/gated — MAC counts are model
    // properties, not machine performance), but parsed so a loaded
    // snapshot is faithful to what was written.
    for row in v.get("per_layer_macs").map(Json::as_arr).unwrap_or(&[]) {
        out.per_layer.push(LayerRow {
            layer: row.num_or("layer", 0.0) as usize,
            executed: row.num_or("executed", 0.0) as u64,
            skipped: row.num_or("skipped", 0.0) as u64,
            keep_ratio: row.num_or("keep_ratio", 1.0),
        });
    }
    Ok(out)
}

/// Load a snapshot from disk.
pub fn load_snapshot(path: &Path) -> Result<BenchPerf, String> {
    let text =
        std::fs::read_to_string(path).map_err(|e| format!("read {}: {e}", path.display()))?;
    snapshot_from_json(&text)
}

// -------------------------------------------------------------- diffing

/// One matched metric across two snapshots.
#[derive(Debug, Clone)]
pub struct DiffRow {
    /// Snapshot section (`engine`, `speedup`, `coord`, `eval`, `div`,
    /// `compile`).
    pub section: &'static str,
    /// Row key inside the section (e.g. `unit/planned`, `workers=4`).
    pub key: String,
    /// Metric name within the row.
    pub metric: &'static str,
    /// Baseline value.
    pub old: f64,
    /// Current value.
    pub new: f64,
    /// Relative change in %, oriented so negative is always *worse*.
    pub delta_pct: f64,
    /// Whether this row participates in the pass/fail gate.
    pub gated: bool,
}

impl DiffRow {
    /// Whether this gated row got worse by more than the tolerance.
    pub fn regressed(&self, tolerance_pct: f64) -> bool {
        self.gated && self.delta_pct < -tolerance_pct
    }
}

/// The matched delta table plus the gate verdict inputs.
#[derive(Debug, Clone)]
pub struct DiffReport {
    /// All matched rows.
    pub rows: Vec<DiffRow>,
    /// Gate tolerance in percent.
    pub tolerance_pct: f64,
}

impl DiffReport {
    /// Gated rows whose metric got worse by more than the tolerance.
    pub fn regressions(&self) -> Vec<&DiffRow> {
        self.rows.iter().filter(|r| r.regressed(self.tolerance_pct)).collect()
    }

    /// Human-readable delta table (one line per matched metric).
    pub fn render(&self) -> String {
        let mut t = crate::util::table::Table::new(vec![
            "section", "row", "metric", "old", "new", "delta", "gate",
        ]);
        for r in &self.rows {
            let verdict = if !r.gated {
                "info"
            } else if r.regressed(self.tolerance_pct) {
                "REGRESSED"
            } else {
                "ok"
            };
            t.row(vec![
                r.section.to_string(),
                r.key.clone(),
                r.metric.to_string(),
                format!("{:.2}", r.old),
                format!("{:.2}", r.new),
                format!("{:+.1}%", r.delta_pct),
                verdict.to_string(),
            ]);
        }
        t.render()
    }
}

/// An informational row for a key present in only one snapshot: the
/// missing side reads 0.00, the delta is 0, and the row is never gated
/// — a delta against a missing side is meaningless, but silently
/// dropping the row would hide that the bench surface changed.
fn one_sided(
    section: &'static str,
    key: String,
    metric: &'static str,
    old_v: Option<f64>,
    new_v: Option<f64>,
) -> DiffRow {
    DiffRow {
        section,
        key,
        metric,
        old: old_v.unwrap_or(0.0),
        new: new_v.unwrap_or(0.0),
        delta_pct: 0.0,
        gated: false,
    }
}

/// Relative delta in %, oriented so "more is better" metrics keep their
/// sign and "less is better" metrics are flipped (negative == worse in
/// both cases). Rows with a non-positive old value cannot be gated
/// meaningfully and are reported as 0.
fn delta_pct(old: f64, new: f64, higher_is_better: bool) -> f64 {
    if old <= 0.0 || !old.is_finite() || !new.is_finite() {
        return 0.0;
    }
    let d = 100.0 * (new - old) / old;
    if higher_is_better {
        d
    } else {
        -d
    }
}

/// Compare two snapshots. Rows are matched by identity (engine rows by
/// mode+backend, speedups by mode, coordinator rows by worker count,
/// eval rows by label, division rows by estimator name). A row or
/// ratio present in only **one** snapshot — a bench section that grew
/// or shrank across versions, e.g. the `simd-interior` /
/// `linear-block` ratios against an older baseline — is reported as an
/// ungated informational row (the missing side shows 0.00, delta 0)
/// instead of being dropped or failing the gate, so evolving the bench
/// never breaks diffs against a committed baseline. With
/// `ratios_only`, only the machine-portable `planned_speedup` ratios
/// are gated.
pub fn diff_snapshots(
    old: &BenchPerf,
    new: &BenchPerf,
    tolerance_pct: f64,
    ratios_only: bool,
) -> DiffReport {
    let mut rows = Vec::new();
    let abs_gate = !ratios_only;

    for o in &old.engine {
        if let Some(n) =
            new.engine.iter().find(|n| n.mode == o.mode && n.backend == o.backend)
        {
            rows.push(DiffRow {
                section: "engine",
                key: format!("{}/{}", o.mode, o.backend),
                metric: "inferences_per_s",
                old: o.inf_per_s,
                new: n.inf_per_s,
                delta_pct: delta_pct(o.inf_per_s, n.inf_per_s, true),
                gated: abs_gate && o.inf_per_s > 0.0,
            });
        } else {
            rows.push(one_sided(
                "engine",
                format!("{}/{}", o.mode, o.backend),
                "inferences_per_s",
                Some(o.inf_per_s),
                None,
            ));
        }
    }
    for n in &new.engine {
        if !old.engine.iter().any(|o| o.mode == n.mode && o.backend == n.backend) {
            rows.push(one_sided(
                "engine",
                format!("{}/{}", n.mode, n.backend),
                "inferences_per_s",
                None,
                Some(n.inf_per_s),
            ));
        }
    }
    for (mode, o) in &old.speedups {
        if let Some((_, n)) = new.speedups.iter().find(|(m, _)| m == mode) {
            rows.push(DiffRow {
                section: "speedup",
                key: format!("planned/{mode}"),
                metric: "ratio",
                old: *o,
                new: *n,
                delta_pct: delta_pct(*o, *n, true),
                gated: *o > 0.0,
            });
        } else {
            rows.push(one_sided("speedup", format!("planned/{mode}"), "ratio", Some(*o), None));
        }
    }
    for (mode, n) in &new.speedups {
        if !old.speedups.iter().any(|(m, _)| m == mode) {
            rows.push(one_sided("speedup", format!("planned/{mode}"), "ratio", None, Some(*n)));
        }
    }
    for o in &old.coord {
        if let Some(n) = new.coord.iter().find(|n| n.workers == o.workers) {
            rows.push(DiffRow {
                section: "coord",
                key: format!("workers={}", o.workers),
                metric: "req_per_s",
                old: o.req_per_s,
                new: n.req_per_s,
                delta_pct: delta_pct(o.req_per_s, n.req_per_s, true),
                gated: abs_gate && o.req_per_s > 0.0,
            });
            rows.push(DiffRow {
                section: "coord",
                key: format!("workers={}", o.workers),
                metric: "queue_p99_us",
                old: o.queue_p99_us as f64,
                new: n.queue_p99_us as f64,
                delta_pct: delta_pct(o.queue_p99_us as f64, n.queue_p99_us as f64, false),
                gated: false, // latency percentiles: informational (noisy)
            });
        } else {
            let key = format!("workers={}", o.workers);
            rows.push(one_sided("coord", key, "req_per_s", Some(o.req_per_s), None));
        }
    }
    for n in &new.coord {
        if !old.coord.iter().any(|o| o.workers == n.workers) {
            let key = format!("workers={}", n.workers);
            rows.push(one_sided("coord", key, "req_per_s", None, Some(n.req_per_s)));
        }
    }
    for o in &old.eval {
        if let Some(n) = new.eval.iter().find(|n| n.label == o.label) {
            rows.push(DiffRow {
                section: "eval",
                key: o.label.clone(),
                metric: "samples_per_s",
                old: o.samples_per_s,
                new: n.samples_per_s,
                delta_pct: delta_pct(o.samples_per_s, n.samples_per_s, true),
                gated: abs_gate && o.samples_per_s > 0.0,
            });
        } else {
            let key = o.label.clone();
            rows.push(one_sided("eval", key, "samples_per_s", Some(o.samples_per_s), None));
        }
    }
    for n in &new.eval {
        if !old.eval.iter().any(|o| o.label == n.label) {
            let key = n.label.clone();
            rows.push(one_sided("eval", key, "samples_per_s", None, Some(n.samples_per_s)));
        }
    }
    for o in &old.divs {
        if let Some(n) = new.divs.iter().find(|n| n.name == o.name) {
            rows.push(DiffRow {
                section: "div",
                key: o.name.clone(),
                metric: "ns_per_op",
                old: o.ns_per_op,
                new: n.ns_per_op,
                delta_pct: delta_pct(o.ns_per_op, n.ns_per_op, false),
                gated: false, // sub-ns timer noise: informational
            });
        } else {
            rows.push(one_sided("div", o.name.clone(), "ns_per_op", Some(o.ns_per_op), None));
        }
    }
    for n in &new.divs {
        if !old.divs.iter().any(|o| o.name == n.name) {
            rows.push(one_sided("div", n.name.clone(), "ns_per_op", None, Some(n.ns_per_op)));
        }
    }
    for o in &old.compile {
        if let Some(n) = new.compile.iter().find(|n| n.label == o.label) {
            rows.push(DiffRow {
                section: "compile",
                key: o.label.clone(),
                metric: "us",
                old: o.us,
                new: n.us,
                delta_pct: delta_pct(o.us, n.us, false),
                gated: false, // absolute compile latency: machine-dependent
            });
        } else {
            rows.push(one_sided("compile", o.label.clone(), "us", Some(o.us), None));
        }
    }
    for n in &new.compile {
        if !old.compile.iter().any(|o| o.label == n.label) {
            rows.push(one_sided("compile", n.label.clone(), "us", None, Some(n.us)));
        }
    }
    DiffReport { rows, tolerance_pct }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn snap(unit_planned: f64, speedup: f64, req4: f64, eval_par: f64) -> BenchPerf {
        BenchPerf {
            model: "mnist".into(),
            engine: vec![
                EngineRow {
                    mode: "unit".into(),
                    backend: "naive".into(),
                    inf_per_s: 100.0,
                    mconn_per_s: 20.0,
                    us_per_inf: 10_000.0,
                },
                EngineRow {
                    mode: "unit".into(),
                    backend: "planned".into(),
                    inf_per_s: unit_planned,
                    mconn_per_s: 60.0,
                    us_per_inf: 1e6 / unit_planned,
                },
            ],
            speedups: vec![("unit".into(), speedup)],
            divs: vec![DivRow { name: "shift".into(), ns_per_op: 2.0 }],
            coord: vec![CoordRow {
                workers: 4,
                req_per_s: req4,
                p50_us: 100,
                p99_us: 300,
                queue_p50_us: 20,
                queue_p99_us: 80,
                service_p50_us: 80,
                service_p99_us: 220,
            }],
            eval: vec![EvalRow { label: "quant-parallel-auto".into(), samples_per_s: eval_par }],
            compile: vec![CompileRow { label: "conv-stamp".into(), us: 150.0 }],
            per_layer: vec![LayerRow::new(0, 3000, 1000)],
        }
    }

    #[test]
    fn compile_rows_roundtrip_and_stay_informational() {
        let old = snap(300.0, 3.0, 1000.0, 800.0);
        let mut new = snapshot_from_json(&old.to_json()).unwrap();
        assert_eq!(new.compile.len(), 1);
        assert_eq!(new.compile[0].label, "conv-stamp");
        // A big compile-latency swing shows in the table but never
        // gates the build (machine-dependent absolute).
        new.compile[0].us = 400.0;
        let report = diff_snapshots(&old, &new, 10.0, false);
        let row = report
            .rows
            .iter()
            .find(|r| r.section == "compile")
            .expect("compile row not diffed");
        assert!(!row.gated);
        assert!(report.regressions().iter().all(|r| r.section != "compile"));
    }

    #[test]
    fn roundtrip_through_json() {
        let a = snap(300.0, 3.0, 1000.0, 800.0);
        let b = snapshot_from_json(&a.to_json()).unwrap();
        assert_eq!(b.model, "mnist");
        assert_eq!(b.engine.len(), 2);
        assert_eq!(b.engine[1].backend, "planned");
        assert_eq!(b.speedups, vec![("unit".to_string(), 3.0)]);
        assert_eq!(b.coord[0].workers, 4);
        assert_eq!(b.coord[0].queue_p99_us, 80);
        assert_eq!(b.eval[0].label, "quant-parallel-auto");
        // per-layer MAC rows survive the round trip, never gated
        assert_eq!(b.per_layer.len(), 1);
        assert_eq!(b.per_layer[0].executed, 3000);
        assert_eq!(b.per_layer[0].keep_ratio, 0.75);
        // identical snapshots diff to all-zero deltas and no regressions
        let report = diff_snapshots(&a, &b, 10.0, false);
        assert!(report.regressions().is_empty());
        assert!(report.rows.iter().all(|r| r.delta_pct == 0.0));
    }

    #[test]
    fn synthetic_regression_over_tolerance_fails_the_gate() {
        let old = snap(300.0, 3.0, 1000.0, 800.0);
        // 20% engine-throughput drop, 15% coordinator drop: both beyond
        // the 10% tolerance — the comparator must flag them.
        let new = snap(240.0, 3.0, 850.0, 800.0);
        let report = diff_snapshots(&old, &new, 10.0, false);
        let regs = report.regressions();
        assert!(!regs.is_empty(), "regression not detected");
        let sections: Vec<_> = regs.iter().map(|r| (r.section, r.metric)).collect();
        assert!(sections.contains(&("engine", "inferences_per_s")));
        assert!(sections.contains(&("coord", "req_per_s")));
        // the rendered table marks them
        assert!(report.render().contains("REGRESSED"));
    }

    #[test]
    fn small_regression_within_tolerance_passes() {
        let old = snap(300.0, 3.0, 1000.0, 800.0);
        let new = snap(285.0, 2.9, 960.0, 770.0); // all within 10%
        assert!(diff_snapshots(&old, &new, 10.0, false).regressions().is_empty());
    }

    #[test]
    fn ratios_only_ignores_absolute_rows_but_gates_speedups() {
        let old = snap(300.0, 3.0, 1000.0, 800.0);
        // Halve every absolute throughput (a slower machine) but keep
        // the planned-vs-naive ratio: no regression in ratios-only mode.
        let mut slower = snap(150.0, 3.0, 500.0, 400.0);
        slower.engine[0].inf_per_s = 50.0;
        assert!(diff_snapshots(&old, &slower, 10.0, true).regressions().is_empty());
        // A collapsed speedup ratio *is* caught in ratios-only mode.
        let collapsed = snap(300.0, 1.5, 1000.0, 800.0);
        let report = diff_snapshots(&old, &collapsed, 10.0, true);
        let regs = report.regressions();
        assert_eq!(regs.len(), 1);
        assert_eq!(regs[0].section, "speedup");
    }

    #[test]
    fn improvements_never_regress() {
        let old = snap(300.0, 3.0, 1000.0, 800.0);
        let new = snap(900.0, 9.0, 3000.0, 2400.0);
        let report = diff_snapshots(&old, &new, 10.0, false);
        assert!(report.regressions().is_empty());
        assert!(report.rows.iter().any(|r| r.delta_pct > 100.0));
    }

    #[test]
    fn unmatched_rows_become_informational_not_regressions() {
        let old = snap(300.0, 3.0, 1000.0, 800.0);
        let mut new = snap(300.0, 3.0, 1000.0, 800.0);
        new.coord[0].workers = 8; // different sweep shape
        new.eval[0].label = "renamed".into();
        let report = diff_snapshots(&old, &new, 10.0, false);
        assert!(report.regressions().is_empty());
        // Both sides of each mismatch surface as ungated info rows
        // with zero delta — visible, but never a gate failure.
        for (section, key) in [
            ("coord", "workers=4"),
            ("coord", "workers=8"),
            ("eval", "quant-parallel-auto"),
            ("eval", "renamed"),
        ] {
            let row = report
                .rows
                .iter()
                .find(|r| r.section == section && r.key == key)
                .unwrap_or_else(|| panic!("{section}/{key} missing from report"));
            assert!(!row.gated, "{section}/{key} one-sided row must not gate");
            assert_eq!(row.delta_pct, 0.0, "{section}/{key} one-sided delta");
        }
    }

    #[test]
    fn new_speedup_ratios_against_old_baseline_are_informational() {
        // The exact shape of a bench evolution: the new snapshot grew
        // `simd-interior` / `linear-block` ratios the committed
        // baseline predates. The diff must gate the shared ratios and
        // pass the new ones through ungated (and the reverse direction
        // — a baseline ratio the bench dropped — likewise).
        let old = snap(300.0, 3.0, 1000.0, 800.0);
        let mut new = snap(300.0, 3.0, 1000.0, 800.0);
        new.speedups.push(("simd-interior".into(), 1.8));
        new.speedups.push(("linear-block".into(), 1.2));
        for ratios_only in [false, true] {
            let report = diff_snapshots(&old, &new, 10.0, ratios_only);
            assert!(report.regressions().is_empty(), "ratios_only={ratios_only}");
            for key in ["planned/simd-interior", "planned/linear-block"] {
                let row = report
                    .rows
                    .iter()
                    .find(|r| r.section == "speedup" && r.key == key)
                    .unwrap_or_else(|| panic!("{key} missing"));
                assert!(!row.gated, "{key} must be informational");
                assert_eq!(row.old, 0.0);
                assert!(row.new > 0.0);
            }
        }
        // Reverse: baseline has a ratio the new run no longer emits.
        let report = diff_snapshots(&new, &old, 10.0, true);
        assert!(report.regressions().is_empty());
        let row = report
            .rows
            .iter()
            .find(|r| r.key == "planned/simd-interior")
            .expect("dropped ratio vanished from report");
        assert!(!row.gated);
        assert_eq!(row.new, 0.0);
    }

    #[test]
    fn parser_handles_null_and_escapes() {
        let v = parse_json(r#"{"a": null, "b": [1, -2.5e1], "c": "x\"y\\z"}"#).unwrap();
        assert_eq!(v.get("a"), Some(&Json::Null));
        assert_eq!(v.get("b").unwrap().as_arr()[1], Json::Num(-25.0));
        assert_eq!(v.get("c").and_then(Json::as_str), Some("x\"y\\z"));
        assert!(parse_json("{\"a\": }").is_err());
        assert!(parse_json("[1, 2] trailing").is_err());
    }

    #[test]
    fn older_snapshot_without_new_sections_still_loads() {
        // A PR-1-era snapshot: no queue/service fields, no quant rows.
        let legacy = r#"{
          "model": "mnist",
          "engine_throughput": [
            {"mode": "unit", "backend": "planned", "inferences_per_s": 300.0,
             "mconn_per_s": 60.0, "us_per_inference": 3333.0}
          ],
          "planned_speedup": {"unit": 3.0},
          "division_ns_per_op": {"shift": 2.0},
          "coordinator": [
            {"workers": 2, "req_per_s": 900.0, "p50_us": 90, "p99_us": 400}
          ],
          "batched_eval": []
        }"#;
        let b = snapshot_from_json(legacy).unwrap();
        assert_eq!(b.coord[0].req_per_s, 900.0);
        assert_eq!(b.coord[0].queue_p99_us, 0);
        let new = snap(300.0, 3.0, 1000.0, 800.0);
        // worker counts differ (2 vs 4) → coord rows unmatched; the
        // speedup row still gates.
        let report = diff_snapshots(&b, &new, 10.0, false);
        assert!(report.regressions().is_empty());
    }
}
