//! Shared experiment harness: prepares trained models and runs the
//! paper's mechanism comparison (None / TTP / FATReLU / UnIT /
//! UnIT+FATReLU / TTP+UnIT) on either execution platform:
//!
//! * [`run_mcu_dataset`] — the MSP430 simulator (mnist / cifar / kws,
//!   the paper's MCU targets): accuracy + MAC skip + modeled
//!   time/energy. Feeds Figs. 5, 6, 7.
//! * [`run_float_dataset`] — the float engine (widar, the paper's
//!   desktop target): accuracy / F1 + MAC skip. Feeds Fig. 5 (widar)
//!   and Table 2.
//!
//! Every bench binary is a thin wrapper over these functions so results
//! are consistent across figures.

use anyhow::Result;

use super::MechanismResult;
use crate::approx::DivKind;
use crate::data::Dataset;
use crate::engine::{PlanConfig, PruneMode, QModel};
use crate::mcu::{cost, EnergyModel};
use crate::models::{zoo, ModelDef, Params};
use crate::nn::{FloatPlan, ForwardOpts};
use crate::pruning::{
    apply_global_magnitude, calibrate, calibrate_fatrelu, CalibConfig, Thresholds,
};
use crate::runtime::{ArtifactStore, Runtime};
use crate::train::{ensure_trained, evaluate_float_plan, evaluate_quant_parallel, TrainConfig};

/// Mechanism sweep options.
#[derive(Debug, Clone)]
pub struct MechOpts {
    /// Division estimator for the UnIT threshold check.
    pub div: DivKind,
    /// Global magnitude sparsity for the TTP baseline.
    pub ttp_sparsity: f64,
    /// Calibration percentile for UnIT thresholds.
    pub calib_pct: f64,
    /// Percentile of positive activations for the FATReLU cut-off.
    pub fat_pct: f64,
    /// Test samples evaluated per mechanism.
    pub n_eval: usize,
    /// Extra scale on calibrated thresholds (sweep knob, default 1).
    pub t_scale: f32,
    /// Worker threads for the fixed-point sweep (0 = all cores). The
    /// result is bit-identical for any value — see
    /// [`crate::train::evaluate_quant_parallel`].
    pub threads: usize,
    /// Dataset/weights seed.
    pub seed: u64,
    /// Training steps when weights must be trained.
    pub train_steps: usize,
}

impl Default for MechOpts {
    fn default() -> Self {
        MechOpts {
            div: DivKind::Shift,
            ttp_sparsity: 0.5,
            calib_pct: 20.0,
            fat_pct: 30.0,
            n_eval: 150,
            t_scale: 1.0,
            threads: 0,
            seed: 42,
            // 0 = use the per-model tuned step count.
            train_steps: 0,
        }
    }
}

/// A trained, calibrated model bundle ready for mechanism evaluation.
pub struct Prepared {
    /// The model definition.
    pub def: ModelDef,
    /// The generated dataset.
    pub ds: Dataset,
    /// Trained parameters.
    pub params: Params,
    /// TTP-pruned parameters.
    pub params_ttp: Params,
    /// Calibrated UnIT thresholds.
    pub thresholds: Thresholds,
    /// Thresholds calibrated on the TTP weights.
    pub thresholds_ttp: Thresholds,
    /// Calibrated FATReLU cut-off.
    pub fat_t: f32,
}

/// Train (or load cached weights), TTP-prune, and calibrate thresholds.
pub fn prepare(
    rt: &Runtime,
    store: &ArtifactStore,
    model: &str,
    opts: &MechOpts,
) -> Result<Prepared> {
    let def = zoo(model);
    let ds = crate::data::by_name(model, opts.seed, crate::data::Sizes::default());
    let mut tcfg = TrainConfig::for_model(model);
    if opts.train_steps > 0 {
        tcfg.steps = opts.train_steps;
    }
    let params = ensure_trained(rt, store, model, &ds, &tcfg)?;
    let params_ttp = apply_global_magnitude(&params, opts.ttp_sparsity);
    let calib = CalibConfig { percentile: opts.calib_pct, ..Default::default() };
    let thresholds = calibrate(&def, &params, &ds.val, &calib).scaled(opts.t_scale);
    let thresholds_ttp = calibrate(&def, &params_ttp, &ds.val, &calib).scaled(opts.t_scale);
    let fat_t = calibrate_fatrelu(&def, &params, &ds.val, opts.fat_pct, 16);
    Ok(Prepared { def, ds, params, params_ttp, thresholds, thresholds_ttp, fat_t })
}

/// The mechanism list of Figs. 5–7 (+ TTP+UnIT from Table 2).
pub const MECHANISMS: [&str; 6] =
    ["None", "TTP", "FATReLU", "UnIT", "UnIT+FATReLU", "TTP+UnIT"];

struct MechSetup {
    label: &'static str,
    params: ParamsChoice,
    mode: PruneMode,
    with_thresholds: bool,
    with_fat: bool,
}

enum ParamsChoice {
    Dense,
    Ttp,
}

fn mechanism_setups() -> Vec<MechSetup> {
    vec![
        MechSetup {
            label: "None",
            params: ParamsChoice::Dense,
            mode: PruneMode::Dense,
            with_thresholds: false,
            with_fat: false,
        },
        MechSetup {
            label: "TTP",
            params: ParamsChoice::Ttp,
            mode: PruneMode::StaticSparse,
            with_thresholds: false,
            with_fat: false,
        },
        MechSetup {
            label: "FATReLU",
            params: ParamsChoice::Dense,
            mode: PruneMode::ZeroSkip,
            with_thresholds: false,
            with_fat: true,
        },
        MechSetup {
            label: "UnIT",
            params: ParamsChoice::Dense,
            mode: PruneMode::Unit,
            with_thresholds: true,
            with_fat: false,
        },
        MechSetup {
            label: "UnIT+FATReLU",
            params: ParamsChoice::Dense,
            mode: PruneMode::Unit,
            with_thresholds: true,
            with_fat: true,
        },
        MechSetup {
            label: "TTP+UnIT",
            params: ParamsChoice::Ttp,
            mode: PruneMode::Unit,
            with_thresholds: true,
            with_fat: false,
        },
    ]
}

/// Evaluate all mechanisms on the MCU simulator. The sweep runs on
/// [`evaluate_quant_parallel`] (one scratch per thread, merged
/// ledgers), so Figs. 5–7 use every core while the per-layer MAC
/// counts and cycle/energy totals stay bit-identical to a sequential
/// pass. Returns `(unpruned_accuracy, rows)`.
pub fn run_mcu_dataset(p: &Prepared, opts: &MechOpts) -> (f64, Vec<MechanismResult>) {
    let energy = EnergyModel::default();
    let n = p.ds.test.len().min(opts.n_eval);
    let mut rows = Vec::new();
    for setup in mechanism_setups() {
        let (params, th) = match setup.params {
            ParamsChoice::Dense => (&p.params, &p.thresholds),
            ParamsChoice::Ttp => (&p.params_ttp, &p.thresholds_ttp),
        };
        let mut q = QModel::quantize(&p.def, params);
        if setup.with_thresholds {
            q = q.with_thresholds(th);
        }
        if setup.with_fat {
            q = q.with_fatrelu(p.fat_t);
        }
        let cfg = PlanConfig::for_mode(setup.mode, opts.div);
        let r = evaluate_quant_parallel(&q, cfg, &p.ds.test, n, opts.threads);
        let nf = n as f64;
        rows.push(MechanismResult {
            mechanism: setup.label.to_string(),
            accuracy: r.accuracy,
            macro_f1: r.macro_f1,
            mac_skipped: r.mac_skipped,
            mcu_secs: cost::cycles_to_secs(r.ledger.total_cycles()) / nf,
            compute_secs: cost::cycles_to_secs(r.ledger.compute_cycles) / nf,
            data_secs: cost::cycles_to_secs(r.ledger.mem_cycles) / nf,
            energy_mj: r.ledger.millijoules(&energy) / nf,
        });
    }
    let baseline = rows[0].accuracy;
    (baseline, rows)
}

/// Evaluate all mechanisms on the float engine (widar / desktop).
///
/// The sweep shares each parameter set's magnitude-sorted tables
/// across mechanisms: one [`FloatPlan::compile`] per `ParamsChoice`,
/// then a [`FloatPlan::restamp`] (conv `w̄` + linear `t` only — the
/// float twin of the quant plan's cut-table stamp) per mechanism row.
pub fn run_float_dataset(p: &Prepared, opts: &MechOpts) -> (f64, Vec<MechanismResult>) {
    let n = opts.n_eval;
    let mut rows = Vec::new();
    let nl = p.def.layers.len();
    let dense_opts = ForwardOpts { t_vec: vec![0.0; nl], fat_t: 0.0 };
    let base_dense = FloatPlan::compile(&p.def, &p.params, &dense_opts);
    let base_ttp = FloatPlan::compile(&p.def, &p.params_ttp, &dense_opts);
    for setup in mechanism_setups() {
        let (base, th) = match setup.params {
            ParamsChoice::Dense => (&base_dense, &p.thresholds),
            ParamsChoice::Ttp => (&base_ttp, &p.thresholds_ttp),
        };
        let t_vec = if setup.with_thresholds {
            th.per_layer.clone()
        } else {
            vec![0.0; nl]
        };
        let fopts =
            ForwardOpts { t_vec, fat_t: if setup.with_fat { p.fat_t } else { 0.0 } };
        let plan = base.restamp(&fopts);
        let r = evaluate_float_plan(&p.def, &plan, &p.ds.test, n);
        rows.push(MechanismResult {
            mechanism: setup.label.to_string(),
            accuracy: r.accuracy,
            macro_f1: r.macro_f1,
            mac_skipped: r.mac_skipped,
            mcu_secs: 0.0,
            compute_secs: 0.0,
            data_secs: 0.0,
            energy_mj: 0.0,
        });
    }
    let baseline = rows[0].accuracy;
    (baseline, rows)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{mnist_like, Sizes};

    /// Prepared bundle without a training run (random weights) for tests.
    fn prepared_random() -> Prepared {
        let def = zoo("mnist");
        let ds = mnist_like::generate(3, Sizes { train: 8, val: 8, test: 16 });
        let params = Params::random(&def, 5);
        let params_ttp = apply_global_magnitude(&params, 0.5);
        let calib = CalibConfig::default();
        let thresholds = calibrate(&def, &params, &ds.val, &calib);
        let thresholds_ttp = calibrate(&def, &params_ttp, &ds.val, &calib);
        let fat_t = calibrate_fatrelu(&def, &params, &ds.val, 30.0, 4);
        Prepared { def, ds, params, params_ttp, thresholds, thresholds_ttp, fat_t }
    }

    #[test]
    fn mcu_mechanism_ordering_holds() {
        let p = prepared_random();
        let opts = MechOpts { n_eval: 6, ..Default::default() };
        let (_base, rows) = run_mcu_dataset(&p, &opts);
        assert_eq!(rows.len(), MECHANISMS.len());
        let by = |name: &str| rows.iter().find(|r| r.mechanism == name).unwrap().clone();
        // The paper's cost ordering: UnIT cheaper than unpruned; TTP+UnIT
        // skips the most MACs.
        assert!(by("UnIT").mcu_secs < by("None").mcu_secs);
        assert!(by("UnIT").energy_mj < by("None").energy_mj);
        assert!(by("TTP+UnIT").mac_skipped >= by("UnIT").mac_skipped);
        assert!(by("TTP+UnIT").mac_skipped >= by("TTP").mac_skipped);
        // Unpruned executes everything.
        assert_eq!(by("None").mac_skipped, 0.0);
    }

    #[test]
    fn float_mechanisms_run_and_skip() {
        let p = prepared_random();
        let opts = MechOpts { n_eval: 4, ..Default::default() };
        let (_base, rows) = run_float_dataset(&p, &opts);
        let by = |name: &str| rows.iter().find(|r| r.mechanism == name).unwrap().clone();
        assert!(by("UnIT").mac_skipped > 0.0);
        assert!(by("TTP").mac_skipped > 0.3); // ~50% weights zeroed
    }
}
