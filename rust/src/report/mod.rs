//! Experiment reporting: paper-style result rows shared by the benches
//! and EXPERIMENTS.md, plus the machine-readable perf trajectory
//! ([`bench`] → `BENCH_perf.json`) and its CI comparator ([`diff`] →
//! `unit bench diff`).

pub mod bench;
pub mod diff;
pub mod experiments;

use crate::util::table::{f, pct, Table};

/// One mechanism's result on one dataset (the Fig. 5/6/7 row unit).
#[derive(Debug, Clone)]
pub struct MechanismResult {
    /// Mechanism label (`unit`, `dense`, …).
    pub mechanism: String,
    /// Top-1 accuracy on the evaluated split.
    pub accuracy: f64,
    /// Macro-averaged F1.
    pub macro_f1: f64,
    /// Fraction of MACs skipped.
    pub mac_skipped: f64,
    /// Modeled MCU seconds per sample (compute + data).
    pub mcu_secs: f64,
    /// Compute-cycle share of `mcu_secs`.
    pub compute_secs: f64,
    /// Memory-traffic share of `mcu_secs`.
    pub data_secs: f64,
    /// Modeled energy per sample (mJ).
    pub energy_mj: f64,
}

/// Render a Fig. 5-style table (accuracy vs remaining MACs).
pub fn fig5_table(dataset: &str, baseline_acc: f64, rows: &[MechanismResult]) -> String {
    let mut t = Table::new(vec![
        "mechanism",
        "accuracy",
        "acc drop",
        "MACs skipped",
        "MACs remaining",
    ]);
    for r in rows {
        t.row(vec![
            r.mechanism.clone(),
            pct(r.accuracy),
            format!("{:+.2}%", 100.0 * (baseline_acc - r.accuracy)),
            pct(r.mac_skipped),
            pct(1.0 - r.mac_skipped),
        ]);
    }
    format!("## Fig.5 [{dataset}]\n{}", t.render())
}

/// Render a Fig. 6-style table (runtime incl. data movement).
pub fn fig6_table(dataset: &str, rows: &[MechanismResult]) -> String {
    let mut t = Table::new(vec!["mechanism", "total s", "compute s", "data-move s"]);
    for r in rows {
        t.row(vec![
            r.mechanism.clone(),
            f(r.mcu_secs, 3),
            f(r.compute_secs, 3),
            f(r.data_secs, 3),
        ]);
    }
    format!("## Fig.6 [{dataset}]\n{}", t.render())
}

/// Render a Fig. 7-style table (energy).
pub fn fig7_table(dataset: &str, rows: &[MechanismResult]) -> String {
    let mut t = Table::new(vec!["mechanism", "energy mJ"]);
    for r in rows {
        t.row(vec![r.mechanism.clone(), f(r.energy_mj, 3)]);
    }
    format!("## Fig.7 [{dataset}]\n{}", t.render())
}

/// Render a Table 2-style block (cross-context F1 + MAC skipped).
pub fn table2(rows: &[(String, String, String, f64, f64)]) -> String {
    let mut t = Table::new(vec!["train ctx", "test ctx", "mechanism", "F1", "MAC skipped"]);
    for (tr, te, mech, f1, skip) in rows {
        t.row(vec![tr.clone(), te.clone(), mech.clone(), f(*f1, 4), pct(*skip)]);
    }
    format!("## Table 2 [widar cross-context]\n{}", t.render())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_row() -> MechanismResult {
        MechanismResult {
            mechanism: "UnIT".into(),
            accuracy: 0.91,
            macro_f1: 0.9,
            mac_skipped: 0.62,
            mcu_secs: 1.5,
            compute_secs: 0.9,
            data_secs: 0.6,
            energy_mj: 0.8,
        }
    }

    #[test]
    fn tables_render_all_mechanisms() {
        let rows = vec![sample_row()];
        let s5 = fig5_table("mnist", 0.95, &rows);
        assert!(s5.contains("UnIT") && s5.contains("62.00%"));
        let s6 = fig6_table("mnist", &rows);
        assert!(s6.contains("1.500"));
        let s7 = fig7_table("mnist", &rows);
        assert!(s7.contains("0.800"));
    }

    #[test]
    fn table2_renders() {
        let rows =
            vec![("room1".into(), "room2".into(), "UnIT".into(), 0.7016, 0.6186)];
        let s = table2(&rows);
        assert!(s.contains("0.7016") && s.contains("61.86%"));
    }
}
