//! Machine-readable perf trajectory: `BENCH_perf.json`.
//!
//! `benches/perf_hotpath.rs` prints human tables *and* serializes the
//! same numbers here so the repo accumulates a comparable perf record
//! from PR to PR (no serde in the vendored set — the writer is a small
//! hand-rolled JSON emitter; keys are fixed identifiers and strings
//! are plain ASCII labels, so escaping is limited to quotes/backslash).

use std::io::Write as _;
use std::path::Path;

/// One engine-throughput measurement (per mode × backend).
#[derive(Debug, Clone)]
pub struct EngineRow {
    /// Pruning mode label.
    pub mode: String,
    /// `"naive"` (reference loops) or `"planned"` (prepacked plans).
    pub backend: String,
    /// Inferences per second.
    pub inf_per_s: f64,
    /// Millions of connections (MACs + skips) per second.
    pub mconn_per_s: f64,
    /// Microseconds per inference.
    pub us_per_inf: f64,
}

/// One division-estimator measurement.
#[derive(Debug, Clone)]
pub struct DivRow {
    /// Estimator name.
    pub name: String,
    /// Nanoseconds per division.
    pub ns_per_op: f64,
}

/// One coordinator round-trip measurement. Queue wait and service
/// time are recorded separately so a shard-balance regression in the
/// work-stealing pool is visible in the perf trajectory (queue
/// percentiles blow up, service stays flat).
#[derive(Debug, Clone, Default)]
pub struct CoordRow {
    /// Worker threads used.
    pub workers: usize,
    /// Completed requests per second.
    pub req_per_s: f64,
    /// Median total latency (µs).
    pub p50_us: u64,
    /// 99th-percentile total latency (µs).
    pub p99_us: u64,
    /// Median queue wait (µs).
    pub queue_p50_us: u64,
    /// 99th-percentile queue wait (µs).
    pub queue_p99_us: u64,
    /// Median service time (µs).
    pub service_p50_us: u64,
    /// 99th-percentile service time (µs).
    pub service_p99_us: u64,
}

/// One batched-eval measurement.
#[derive(Debug, Clone)]
pub struct EvalRow {
    /// Measurement label.
    pub label: String,
    /// Samples evaluated per second.
    pub samples_per_s: f64,
}

/// One plan-compile latency measurement (µs): what a scale change
/// costs at each tier — full compile, cut-table stamp, cache-hit
/// swap, background miss→upgrade.
#[derive(Debug, Clone)]
pub struct CompileRow {
    /// Tier label (`full`, `stamp`, `hit`, …).
    pub label: String,
    /// Microseconds per operation.
    pub us: f64,
}

/// Per-layer MAC accounting for one representative pruned inference
/// (section `per_layer_macs`): where the paper's skipping actually
/// lands, layer by layer. The same numbers the serving stack exports
/// live as `unit_layer_macs_total` / `unit_layer_keep_ratio`.
#[derive(Debug, Clone)]
pub struct LayerRow {
    /// Layer index within the plan.
    pub layer: usize,
    /// MACs executed.
    pub executed: u64,
    /// MACs skipped by the threshold check.
    pub skipped: u64,
    /// `executed / (executed + skipped)`; 1.0 for an empty layer.
    pub keep_ratio: f64,
}

impl LayerRow {
    /// Build a row from an inference's per-layer kept/skipped counts.
    pub fn new(layer: usize, executed: u64, skipped: u64) -> LayerRow {
        let total = executed + skipped;
        let keep_ratio = if total > 0 { executed as f64 / total as f64 } else { 1.0 };
        LayerRow { layer, executed, skipped, keep_ratio }
    }
}

/// The full perf snapshot emitted by `perf_hotpath`.
#[derive(Debug, Clone, Default)]
pub struct BenchPerf {
    /// Model the snapshot was taken on.
    pub model: String,
    /// Engine-throughput rows.
    pub engine: Vec<EngineRow>,
    /// Planned-vs-naive throughput ratios per mode (plus the
    /// lane-vs-scalar conv interior ratio, key `conv-lane`).
    pub speedups: Vec<(String, f64)>,
    /// Division-estimator rows.
    pub divs: Vec<DivRow>,
    /// Coordinator round-trip rows.
    pub coord: Vec<CoordRow>,
    /// Batched-eval rows.
    pub eval: Vec<EvalRow>,
    /// Plan-compile latency tiers (section `plan_compile_us`).
    pub compile: Vec<CompileRow>,
    /// Per-layer MAC accounting rows (section `per_layer_macs`).
    pub per_layer: Vec<LayerRow>,
}

fn esc(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

fn num(x: f64) -> String {
    if x.is_finite() {
        format!("{x:.3}")
    } else {
        "null".to_string()
    }
}

impl BenchPerf {
    /// Serialize the snapshot as pretty-printed JSON.
    pub fn to_json(&self) -> String {
        let mut out = String::from("{\n");
        out.push_str(&format!("  \"model\": \"{}\",\n", esc(&self.model)));
        out.push_str("  \"engine_throughput\": [\n");
        for (i, r) in self.engine.iter().enumerate() {
            out.push_str(&format!(
                "    {{\"mode\": \"{}\", \"backend\": \"{}\", \"inferences_per_s\": {}, \
                 \"mconn_per_s\": {}, \"us_per_inference\": {}}}{}\n",
                esc(&r.mode),
                esc(&r.backend),
                num(r.inf_per_s),
                num(r.mconn_per_s),
                num(r.us_per_inf),
                if i + 1 < self.engine.len() { "," } else { "" }
            ));
        }
        out.push_str("  ],\n  \"planned_speedup\": {");
        for (i, (mode, s)) in self.speedups.iter().enumerate() {
            out.push_str(&format!(
                "{}\"{}\": {}",
                if i > 0 { ", " } else { "" },
                esc(mode),
                num(*s)
            ));
        }
        out.push_str("},\n  \"division_ns_per_op\": {");
        for (i, d) in self.divs.iter().enumerate() {
            out.push_str(&format!(
                "{}\"{}\": {}",
                if i > 0 { ", " } else { "" },
                esc(&d.name),
                num(d.ns_per_op)
            ));
        }
        out.push_str("},\n  \"coordinator\": [\n");
        for (i, c) in self.coord.iter().enumerate() {
            out.push_str(&format!(
                "    {{\"workers\": {}, \"req_per_s\": {}, \"p50_us\": {}, \"p99_us\": {}, \
                 \"queue_p50_us\": {}, \"queue_p99_us\": {}, \"service_p50_us\": {}, \
                 \"service_p99_us\": {}}}{}\n",
                c.workers,
                num(c.req_per_s),
                c.p50_us,
                c.p99_us,
                c.queue_p50_us,
                c.queue_p99_us,
                c.service_p50_us,
                c.service_p99_us,
                if i + 1 < self.coord.len() { "," } else { "" }
            ));
        }
        out.push_str("  ],\n  \"batched_eval\": [\n");
        for (i, e) in self.eval.iter().enumerate() {
            out.push_str(&format!(
                "    {{\"label\": \"{}\", \"samples_per_s\": {}}}{}\n",
                esc(&e.label),
                num(e.samples_per_s),
                if i + 1 < self.eval.len() { "," } else { "" }
            ));
        }
        out.push_str("  ],\n  \"plan_compile_us\": [\n");
        for (i, c) in self.compile.iter().enumerate() {
            out.push_str(&format!(
                "    {{\"label\": \"{}\", \"us\": {}}}{}\n",
                esc(&c.label),
                num(c.us),
                if i + 1 < self.compile.len() { "," } else { "" }
            ));
        }
        out.push_str("  ],\n  \"per_layer_macs\": [\n");
        for (i, l) in self.per_layer.iter().enumerate() {
            out.push_str(&format!(
                "    {{\"layer\": {}, \"executed\": {}, \"skipped\": {}, \
                 \"keep_ratio\": {}}}{}\n",
                l.layer,
                l.executed,
                l.skipped,
                num(l.keep_ratio),
                if i + 1 < self.per_layer.len() { "," } else { "" }
            ));
        }
        out.push_str("  ]\n}\n");
        out
    }

    /// Write the JSON to `path` (creating parent dirs as needed).
    pub fn write(&self, path: &Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        let mut f = std::fs::File::create(path)?;
        f.write_all(self.to_json().as_bytes())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_shape_and_escaping() {
        let b = BenchPerf {
            model: "mnist".into(),
            engine: vec![
                EngineRow {
                    mode: "unit".into(),
                    backend: "naive".into(),
                    inf_per_s: 100.0,
                    mconn_per_s: 24.5,
                    us_per_inf: 10000.0,
                },
                EngineRow {
                    mode: "unit".into(),
                    backend: "planned".into(),
                    inf_per_s: 300.0,
                    mconn_per_s: 73.5,
                    us_per_inf: 3333.0,
                },
            ],
            speedups: vec![("unit".into(), 3.0)],
            divs: vec![DivRow { name: "shift\"x".into(), ns_per_op: 1.25 }],
            coord: vec![CoordRow {
                workers: 2,
                req_per_s: 1000.0,
                p50_us: 90,
                p99_us: 400,
                queue_p50_us: 30,
                queue_p99_us: 200,
                service_p50_us: 60,
                service_p99_us: 210,
            }],
            eval: vec![EvalRow { label: "parallel-4".into(), samples_per_s: 800.0 }],
            compile: vec![CompileRow { label: "conv-stamp".into(), us: 120.5 }],
            per_layer: vec![LayerRow::new(0, 300, 100), LayerRow::new(1, 0, 0)],
        };
        let j = b.to_json();
        assert!(j.contains("\"planned_speedup\": {\"unit\": 3.000}"));
        assert!(j.contains("\"backend\": \"planned\""));
        assert!(j.contains("\"plan_compile_us\""));
        assert!(j.contains("\"label\": \"conv-stamp\", \"us\": 120.500"));
        assert!(j.contains(
            "{\"layer\": 0, \"executed\": 300, \"skipped\": 100, \"keep_ratio\": 0.750}"
        ));
        // An empty layer reports keep_ratio 1.0, not NaN/null.
        assert!(
            j.contains("{\"layer\": 1, \"executed\": 0, \"skipped\": 0, \"keep_ratio\": 1.000}")
        );
        assert!(j.contains("shift\\\"x"));
        // balanced braces/brackets (cheap well-formedness check)
        assert_eq!(j.matches('{').count(), j.matches('}').count());
        assert_eq!(j.matches('[').count(), j.matches(']').count());
    }

    #[test]
    fn write_roundtrip() {
        let dir = std::env::temp_dir().join("unit_pruner_bench_json");
        let path = dir.join("BENCH_perf.json");
        let b = BenchPerf { model: "mnist".into(), ..Default::default() };
        b.write(&path).unwrap();
        let s = std::fs::read_to_string(&path).unwrap();
        assert!(s.contains("\"model\": \"mnist\""));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn non_finite_becomes_null() {
        assert_eq!(num(f64::INFINITY), "null");
        assert_eq!(num(1.5), "1.500");
    }
}
