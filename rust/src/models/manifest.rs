//! Parser for the `artifacts/<ds>_manifest.txt` files the Python AOT
//! exporter writes (the flat param ABI shared between layers 2 and 3).
//!
//! Line format (deliberately trivial — no serde in the vendored set):
//! ```text
//! model mnist
//! input 1 28 28
//! classes 10
//! prunable 3
//! param l0.w 6 1 5 5
//! ...
//! macs 0 86400
//! ```

use anyhow::{bail, Context, Result};

/// Parsed manifest: the authoritative description of the exported HLO's
/// parameter order and shapes.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Model name.
    pub model: String,
    /// Input shape as `[C, H, W]`.
    pub input_shape: [usize; 3],
    /// Output classes.
    pub classes: usize,
    /// Number of prunable layers.
    pub prunable: usize,
    /// `(name, shape)` in HLO parameter order.
    pub params: Vec<(String, Vec<usize>)>,
    /// Dense MACs per prunable layer.
    pub macs: Vec<u64>,
}

impl Manifest {
    /// Parse manifest text.
    pub fn parse(text: &str) -> Result<Manifest> {
        let mut model = None;
        let mut input_shape = None;
        let mut classes = None;
        let mut prunable = None;
        let mut params = Vec::new();
        let mut macs = Vec::new();
        for (ln, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let mut it = line.split_whitespace();
            let kind = it.next().unwrap();
            let rest: Vec<&str> = it.collect();
            match kind {
                "model" => model = Some(rest.first().context("model name")?.to_string()),
                "input" => {
                    if rest.len() != 3 {
                        bail!("line {ln}: input needs 3 dims");
                    }
                    let d: Vec<usize> =
                        rest.iter().map(|s| s.parse()).collect::<Result<_, _>>()?;
                    input_shape = Some([d[0], d[1], d[2]]);
                }
                "classes" => classes = Some(rest[0].parse()?),
                "prunable" => prunable = Some(rest[0].parse()?),
                "param" => {
                    let name = rest.first().context("param name")?.to_string();
                    let shape: Vec<usize> =
                        rest[1..].iter().map(|s| s.parse()).collect::<Result<_, _>>()?;
                    params.push((name, shape));
                }
                "macs" => {
                    let idx: usize = rest[0].parse()?;
                    if idx != macs.len() {
                        bail!("line {ln}: macs lines out of order");
                    }
                    macs.push(rest[1].parse()?);
                }
                other => bail!("line {ln}: unknown record {other}"),
            }
        }
        Ok(Manifest {
            model: model.context("missing model line")?,
            input_shape: input_shape.context("missing input line")?,
            classes: classes.context("missing classes line")?,
            prunable: prunable.context("missing prunable line")?,
            params,
            macs,
        })
    }

    /// Read and parse a manifest file.
    pub fn load(path: &std::path::Path) -> Result<Manifest> {
        let text =
            std::fs::read_to_string(path).with_context(|| format!("reading {path:?}"))?;
        Self::parse(&text)
    }

    /// Check consistency against the Rust-side zoo definition.
    pub fn check_against(&self, def: &super::ModelDef) -> Result<()> {
        if self.model != def.name {
            bail!("manifest model {} vs zoo {}", self.model, def.name);
        }
        if self.input_shape != def.input_shape {
            bail!("input shape mismatch");
        }
        if self.classes != def.classes {
            bail!("classes mismatch");
        }
        if self.prunable != def.layers.len() {
            bail!("prunable layer count mismatch");
        }
        let zoo_macs = def.dense_macs();
        if self.macs != zoo_macs {
            bail!("dense MAC mismatch: manifest {:?} vs zoo {:?}", self.macs, zoo_macs);
        }
        // params: 2 per layer (w, b), element counts must match
        if self.params.len() != 2 * def.layers.len() {
            bail!("param count mismatch");
        }
        for (li, layer) in def.layers.iter().enumerate() {
            let (wc, bc) = layer.param_counts();
            let wm: usize = self.params[2 * li].1.iter().product();
            let bm: usize = self.params[2 * li + 1].1.iter().product();
            if wm != wc || bm != bc {
                bail!("layer {li} param size mismatch: ({wm},{bm}) vs ({wc},{bc})");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
model mnist
input 1 28 28
classes 10
prunable 3
param l0.w 6 1 5 5
param l0.b 6
param l1.w 16 6 5 5
param l1.b 16
param l2.w 256 10
param l2.b 10
macs 0 86400
macs 1 153600
macs 2 2560
";

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.model, "mnist");
        assert_eq!(m.input_shape, [1, 28, 28]);
        assert_eq!(m.params.len(), 6);
        assert_eq!(m.macs, vec![86_400, 153_600, 2_560]);
    }

    #[test]
    fn checks_against_zoo() {
        let m = Manifest::parse(SAMPLE).unwrap();
        m.check_against(&crate::models::zoo("mnist")).unwrap();
    }

    #[test]
    fn rejects_wrong_macs() {
        let bad = SAMPLE.replace("macs 2 2560", "macs 2 9999");
        let m = Manifest::parse(&bad).unwrap();
        assert!(m.check_against(&crate::models::zoo("mnist")).is_err());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Manifest::parse("bogus line here").is_err());
        assert!(Manifest::parse("model x\ninput 1 2\nclasses 1\nprunable 0").is_err());
    }
}
