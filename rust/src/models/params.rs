//! Model parameters: per-layer weight/bias storage and a tiny binary
//! (de)serialization format for `artifacts/weights/<ds>.bin`.
//!
//! Format: magic `UNITW1\n`, then per tensor: `u32 name_len | name |
//! u32 rank | u64 dims... | f32 data...` — all little-endian. Written by
//! the trainer after the PJRT training run; read by every experiment so
//! models are trained once and reused.

use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::path::Path;

/// Flat per-layer parameters (weights row-major as exported by JAX:
/// conv `O×I×KH×KW`, linear `N_in×N_out`).
#[derive(Debug, Clone, PartialEq)]
pub struct Params {
    /// Per-layer flat weights.
    pub weights: Vec<Vec<f32>>,
    /// Per-layer biases.
    pub biases: Vec<Vec<f32>>,
}

const MAGIC: &[u8] = b"UNITW1\n";

impl Params {
    /// Zero-initialized parameters matching a model definition.
    pub fn zeros(def: &super::ModelDef) -> Params {
        let mut weights = Vec::new();
        let mut biases = Vec::new();
        for l in &def.layers {
            let (wc, bc) = l.param_counts();
            weights.push(vec![0.0; wc]);
            biases.push(vec![0.0; bc]);
        }
        Params { weights, biases }
    }

    /// He-normal random init (for tests that need a nonzero model
    /// without a training run).
    pub fn random(def: &super::ModelDef, seed: u64) -> Params {
        let mut rng = crate::util::Rng::new(seed);
        let mut p = Params::zeros(def);
        for (li, l) in def.layers.iter().enumerate() {
            let fan_in = match *l {
                crate::nn::Layer::Conv { in_ch, kh, kw, .. } => in_ch * kh * kw,
                crate::nn::Layer::Linear { n_in, .. } => n_in,
            };
            let std = (2.0 / fan_in as f32).sqrt();
            for w in p.weights[li].iter_mut() {
                *w = std * rng.normal();
            }
        }
        p
    }

    /// Interleaved `[w0, b0, w1, b1, ...]` flat views, the HLO param order.
    pub fn flat_order(&self) -> Vec<&[f32]> {
        let mut out = Vec::with_capacity(2 * self.weights.len());
        for (w, b) in self.weights.iter().zip(&self.biases) {
            out.push(w.as_slice());
            out.push(b.as_slice());
        }
        out
    }

    /// Rebuild from interleaved flat tensors (inverse of `flat_order`).
    pub fn from_flat_order(tensors: Vec<Vec<f32>>) -> Result<Params> {
        if tensors.len() % 2 != 0 {
            bail!("expected interleaved w/b tensors");
        }
        let mut weights = Vec::new();
        let mut biases = Vec::new();
        for (i, t) in tensors.into_iter().enumerate() {
            if i % 2 == 0 {
                weights.push(t);
            } else {
                biases.push(t);
            }
        }
        Ok(Params { weights, biases })
    }

    /// Write the binary weights format (creates parent dirs).
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        f.write_all(MAGIC)?;
        for (li, (w, b)) in self.weights.iter().zip(&self.biases).enumerate() {
            for (tag, data) in [("w", w), ("b", b)] {
                let name = format!("l{li}.{tag}");
                f.write_all(&(name.len() as u32).to_le_bytes())?;
                f.write_all(name.as_bytes())?;
                f.write_all(&(1u32).to_le_bytes())?; // rank 1: flat
                f.write_all(&(data.len() as u64).to_le_bytes())?;
                for v in data {
                    f.write_all(&v.to_le_bytes())?;
                }
            }
        }
        Ok(())
    }

    /// Read the binary weights format.
    pub fn load(path: &Path) -> Result<Params> {
        let mut f = std::io::BufReader::new(
            std::fs::File::open(path).with_context(|| format!("opening {path:?}"))?,
        );
        let mut magic = [0u8; 7];
        f.read_exact(&mut magic)?;
        if magic != MAGIC {
            bail!("bad magic in {path:?}");
        }
        let mut tensors = Vec::new();
        loop {
            let mut len4 = [0u8; 4];
            match f.read_exact(&mut len4) {
                Ok(()) => {}
                Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => break,
                Err(e) => return Err(e.into()),
            }
            let name_len = u32::from_le_bytes(len4) as usize;
            let mut name = vec![0u8; name_len];
            f.read_exact(&mut name)?;
            let mut rank4 = [0u8; 4];
            f.read_exact(&mut rank4)?;
            let rank = u32::from_le_bytes(rank4) as usize;
            let mut total = 1usize;
            for _ in 0..rank {
                let mut d8 = [0u8; 8];
                f.read_exact(&mut d8)?;
                total *= u64::from_le_bytes(d8) as usize;
            }
            let mut data = vec![0f32; total];
            let mut buf = [0u8; 4];
            for v in data.iter_mut() {
                f.read_exact(&mut buf)?;
                *v = f32::from_le_bytes(buf);
            }
            tensors.push(data);
        }
        Params::from_flat_order(tensors)
    }

    /// Global max |w| (used by quantization sanity checks).
    pub fn max_abs_weight(&self) -> f32 {
        self.weights
            .iter()
            .flat_map(|w| w.iter())
            .fold(0f32, |m, &v| m.max(v.abs()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn save_load_roundtrip() {
        let def = crate::models::zoo("mnist");
        let p = Params::random(&def, 3);
        let dir = std::env::temp_dir().join("unit_pruner_test_params");
        let path = dir.join("mnist.bin");
        p.save(&path).unwrap();
        let q = Params::load(&path).unwrap();
        assert_eq!(p, q);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn flat_order_interleaves() {
        let def = crate::models::zoo("mnist");
        let p = Params::zeros(&def);
        let flat = p.flat_order();
        assert_eq!(flat.len(), 6);
        assert_eq!(flat[0].len(), 150); // l0.w 6*1*5*5
        assert_eq!(flat[1].len(), 6); // l0.b
        assert_eq!(flat[4].len(), 2560); // l2.w
    }

    #[test]
    fn from_flat_order_roundtrip() {
        let def = crate::models::zoo("cifar");
        let p = Params::random(&def, 7);
        let flat: Vec<Vec<f32>> = p.flat_order().into_iter().map(|s| s.to_vec()).collect();
        let q = Params::from_flat_order(flat).unwrap();
        assert_eq!(p, q);
    }

    #[test]
    fn random_has_nonzero_weights_zero_biases() {
        let def = crate::models::zoo("widar");
        let p = Params::random(&def, 1);
        assert!(p.max_abs_weight() > 0.0);
        assert!(p.biases.iter().all(|b| b.iter().all(|&v| v == 0.0)));
    }

    #[test]
    fn load_rejects_bad_magic() {
        let dir = std::env::temp_dir().join("unit_pruner_test_badmagic");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.bin");
        std::fs::write(&path, b"NOTMAGIC").unwrap();
        assert!(Params::load(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
