//! Model zoo (paper Table 1), parameter storage, and the manifest ABI
//! shared with the Python AOT exporter.

pub mod manifest;
pub mod params;

pub use manifest::Manifest;
pub use params::Params;

use crate::nn::Layer;

/// A sequential Table-1 model definition.
#[derive(Debug, Clone)]
pub struct ModelDef {
    /// Zoo name (`mnist`, `cifar`, `kws`, `widar`).
    pub name: String,
    /// Input shape as `[C, H, W]`.
    pub input_shape: [usize; 3],
    /// Output classes.
    pub classes: usize,
    /// Layers in execution order.
    pub layers: Vec<Layer>,
}

impl ModelDef {
    /// Flattened input length (C·H·W).
    pub fn input_len(&self) -> usize {
        self.input_shape.iter().product()
    }

    /// Dense MACs per prunable layer (Fig. 5 denominators).
    pub fn dense_macs(&self) -> Vec<u64> {
        let mut shape = self.input_shape;
        self.layers
            .iter()
            .map(|l| {
                let (m, s) = l.dense_macs(shape);
                shape = s;
                m
            })
            .collect()
    }

    /// Dense MACs summed over all layers.
    pub fn total_dense_macs(&self) -> u64 {
        self.dense_macs().iter().sum()
    }

    /// Activation sizes flowing *into* each layer plus the final output
    /// (used by the FRAM traffic model).
    pub fn activation_sizes(&self) -> Vec<usize> {
        let mut out = vec![self.input_len()];
        let mut shape = self.input_shape;
        for l in &self.layers {
            let (_, s) = l.dense_macs(shape);
            shape = s;
            out.push(shape.iter().product());
        }
        out
    }
}

/// The four Table-1 architectures by dataset name.
pub fn zoo(name: &str) -> ModelDef {
    match name {
        "mnist" => ModelDef {
            name: "mnist".into(),
            input_shape: [1, 28, 28],
            classes: 10,
            layers: vec![
                Layer::Conv { out_ch: 6, in_ch: 1, kh: 5, kw: 5, pool: true },
                Layer::Conv { out_ch: 16, in_ch: 6, kh: 5, kw: 5, pool: true },
                Layer::Linear { n_in: 256, n_out: 10, relu: false },
            ],
        },
        "cifar" => ModelDef {
            name: "cifar".into(),
            input_shape: [3, 32, 32],
            classes: 10,
            layers: vec![
                Layer::Conv { out_ch: 6, in_ch: 3, kh: 5, kw: 5, pool: true },
                Layer::Conv { out_ch: 16, in_ch: 6, kh: 5, kw: 5, pool: true },
                Layer::Linear { n_in: 400, n_out: 10, relu: false },
            ],
        },
        "kws" => ModelDef {
            name: "kws".into(),
            input_shape: [1, 124, 80],
            classes: 12,
            layers: vec![
                Layer::Conv { out_ch: 6, in_ch: 1, kh: 5, kw: 5, pool: true },
                Layer::Conv { out_ch: 16, in_ch: 6, kh: 5, kw: 5, pool: true },
                Layer::Linear { n_in: 7616, n_out: 12, relu: false },
            ],
        },
        "widar" => ModelDef {
            name: "widar".into(),
            input_shape: [22, 13, 13],
            classes: 6,
            layers: vec![
                Layer::Conv { out_ch: 32, in_ch: 22, kh: 6, kw: 6, pool: false },
                Layer::Conv { out_ch: 64, in_ch: 32, kh: 3, kw: 3, pool: false },
                Layer::Conv { out_ch: 96, in_ch: 64, kh: 3, kw: 3, pool: false },
                Layer::Linear { n_in: 1536, n_out: 128, relu: true },
                Layer::Linear { n_in: 128, n_out: 6, relu: false },
            ],
        },
        other => panic!("unknown model {other}"),
    }
}

/// The four Table-1 zoo model names.
pub const MODEL_NAMES: [&str; 4] = ["mnist", "cifar", "kws", "widar"];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zoo_matches_table1_linear_inputs() {
        // Table 1: L 256x10, 400x10, 7616x12, 1536x128 + 128x6.
        for (name, want) in [("mnist", 256), ("cifar", 400), ("kws", 7616), ("widar", 1536)] {
            let m = zoo(name);
            let lin = m
                .layers
                .iter()
                .find_map(|l| match *l {
                    Layer::Linear { n_in, .. } => Some(n_in),
                    _ => None,
                })
                .unwrap();
            assert_eq!(lin, want, "{name}");
        }
    }

    #[test]
    fn shapes_flow_end_to_end() {
        // dense_macs() panics internally on any shape mismatch.
        for name in MODEL_NAMES {
            let m = zoo(name);
            let macs = m.dense_macs();
            assert_eq!(macs.len(), m.layers.len());
            assert!(m.total_dense_macs() > 0);
        }
    }

    #[test]
    fn activation_sizes_bookends() {
        let m = zoo("mnist");
        let a = m.activation_sizes();
        assert_eq!(a[0], 28 * 28);
        assert_eq!(*a.last().unwrap(), 10);
    }

    #[test]
    fn kws_is_largest_model() {
        // Fig. 6: KWS has the longest runtime — MAC ordering must agree.
        let kws = zoo("kws").total_dense_macs();
        let mnist = zoo("mnist").total_dense_macs();
        let cifar = zoo("cifar").total_dense_macs();
        assert!(kws > cifar && kws > mnist);
    }
}
