//! Thin wrapper around the `xla` crate's PJRT CPU client.
//!
//! Interchange is HLO **text** (`HloModuleProto::from_text_file`): jax
//! ≥ 0.5 serialized protos carry 64-bit instruction ids that
//! xla_extension 0.5.1 rejects; the text parser reassigns ids (see
//! /opt/xla-example/README.md). All exported computations return a
//! tuple (lowered with `return_tuple=True`), decomposed with
//! `Literal::to_tuple`.

use anyhow::{Context, Result};
use std::path::Path;

/// A live PJRT CPU client.
pub struct Runtime {
    client: xla::PjRtClient,
}

/// One compiled computation plus its input shape signature.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    /// Dims per input parameter (row-major; `[]` = scalar).
    pub arg_shapes: Vec<Vec<usize>>,
}

impl Runtime {
    /// Create the in-process CPU client (one per process is plenty).
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO text artifact.
    ///
    /// `arg_shapes` declares the parameter shapes in order (needed to
    /// build input literals; the manifest provides them).
    pub fn load_hlo(&self, path: &Path, arg_shapes: Vec<Vec<usize>>) -> Result<Executable> {
        let path_str = path.to_str().context("non-utf8 path")?;
        let proto = xla::HloModuleProto::from_text_file(path_str)
            .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).with_context(|| format!("compiling {path:?}"))?;
        Ok(Executable { exe, arg_shapes })
    }
}

impl Executable {
    /// Execute with f32 inputs matching the declared shapes; returns the
    /// decomposed output tuple as flat f32 vectors.
    pub fn run_f32(&self, args: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        anyhow::ensure!(
            args.len() == self.arg_shapes.len(),
            "arity mismatch: {} args vs {} declared",
            args.len(),
            self.arg_shapes.len()
        );
        let mut literals = Vec::with_capacity(args.len());
        for (a, shape) in args.iter().zip(&self.arg_shapes) {
            let expect: usize = shape.iter().product();
            anyhow::ensure!(
                a.len() == expect,
                "arg length {} vs shape {:?}",
                a.len(),
                shape
            );
            let lit = if shape.is_empty() {
                xla::Literal::from(a[0])
            } else {
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(a).reshape(&dims)?
            };
            literals.push(lit);
        }
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        let parts = result.to_tuple()?;
        parts
            .into_iter()
            .map(|l| l.to_vec::<f32>().map_err(Into::into))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    // PJRT integration tests live in rust/tests/pjrt_roundtrip.rs (they
    // need the artifacts directory); here we only check client creation
    // so `cargo test --lib` stays artifact-free.
    #[test]
    fn cpu_client_comes_up() {
        let rt = super::Runtime::cpu().unwrap();
        assert_eq!(rt.platform(), "cpu");
    }
}
