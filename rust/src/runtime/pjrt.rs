//! Thin wrapper around the `xla` crate's PJRT CPU client.
//!
//! Interchange is HLO **text** (`HloModuleProto::from_text_file`): jax
//! ≥ 0.5 serialized protos carry 64-bit instruction ids that
//! xla_extension 0.5.1 rejects; the text parser reassigns ids (see
//! /opt/xla-example/README.md). All exported computations return a
//! tuple (lowered with `return_tuple=True`), decomposed with
//! `Literal::to_tuple`.
//!
//! ## Feature gating
//!
//! The `xla` crate is not part of the offline vendor set, so the real
//! client only compiles under the `xla` cargo feature. The default
//! build gets an API-identical stub whose constructors return an error
//! — every PJRT consumer (trainer, Pjrt serving backend, artifact
//! tests) already treats `Runtime::cpu()` as fallible, so the MCU
//! simulator, the planned engine, and the whole serving path work
//! without XLA present.

#[cfg(feature = "xla")]
mod imp {
    use anyhow::{Context, Result};
    use std::path::Path;

    /// A live PJRT CPU client.
    pub struct Runtime {
        client: xla::PjRtClient,
    }

    /// One compiled computation plus its input shape signature.
    pub struct Executable {
        exe: xla::PjRtLoadedExecutable,
        /// Dims per input parameter (row-major; `[]` = scalar).
        pub arg_shapes: Vec<Vec<usize>>,
    }

    impl Runtime {
        /// Create the in-process CPU client (one per process is plenty).
        pub fn cpu() -> Result<Runtime> {
            let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
            Ok(Runtime { client })
        }

        /// PJRT platform name (e.g. `"cpu"`).
        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Load + compile an HLO text artifact.
        ///
        /// `arg_shapes` declares the parameter shapes in order (needed to
        /// build input literals; the manifest provides them).
        pub fn load_hlo(&self, path: &Path, arg_shapes: Vec<Vec<usize>>) -> Result<Executable> {
            let path_str = path.to_str().context("non-utf8 path")?;
            let proto = xla::HloModuleProto::from_text_file(path_str)
                .with_context(|| format!("parsing HLO text {path:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe =
                self.client.compile(&comp).with_context(|| format!("compiling {path:?}"))?;
            Ok(Executable { exe, arg_shapes })
        }
    }

    impl Executable {
        /// Execute with f32 inputs matching the declared shapes; returns the
        /// decomposed output tuple as flat f32 vectors.
        pub fn run_f32(&self, args: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
            anyhow::ensure!(
                args.len() == self.arg_shapes.len(),
                "arity mismatch: {} args vs {} declared",
                args.len(),
                self.arg_shapes.len()
            );
            let mut literals = Vec::with_capacity(args.len());
            for (a, shape) in args.iter().zip(&self.arg_shapes) {
                let expect: usize = shape.iter().product();
                anyhow::ensure!(
                    a.len() == expect,
                    "arg length {} vs shape {:?}",
                    a.len(),
                    shape
                );
                let lit = if shape.is_empty() {
                    xla::Literal::from(a[0])
                } else {
                    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                    xla::Literal::vec1(a).reshape(&dims)?
                };
                literals.push(lit);
            }
            let result = self.exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
            let parts = result.to_tuple()?;
            parts
                .into_iter()
                .map(|l| l.to_vec::<f32>().map_err(Into::into))
                .collect()
        }
    }
}

#[cfg(not(feature = "xla"))]
mod imp {
    use anyhow::{bail, Result};
    use std::path::Path;

    /// Stub PJRT client: the crate was built without the `xla` feature.
    pub struct Runtime {
        _private: (),
    }

    /// Stub executable (never constructed without the `xla` feature).
    pub struct Executable {
        /// Dims per input parameter (row-major; `[]` = scalar).
        pub arg_shapes: Vec<Vec<usize>>,
    }

    impl Runtime {
        /// Stub: always fails — built without the `xla` feature.
        pub fn cpu() -> Result<Runtime> {
            bail!(
                "PJRT unavailable: unit_pruner was built without the `xla` \
                 feature (the xla crate is not in the offline vendor set). \
                 MCU-simulator and planned-engine paths are unaffected."
            )
        }

        /// Stub platform name (`"stub"`).
        pub fn platform(&self) -> String {
            "stub".to_string()
        }

        /// Stub: always fails — built without the `xla` feature.
        pub fn load_hlo(&self, _path: &Path, _arg_shapes: Vec<Vec<usize>>) -> Result<Executable> {
            bail!("PJRT unavailable: built without the `xla` feature")
        }
    }

    impl Executable {
        /// Stub: always fails — built without the `xla` feature.
        pub fn run_f32(&self, _args: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
            bail!("PJRT unavailable: built without the `xla` feature")
        }
    }
}

pub use imp::{Executable, Runtime};

/// True when this build can actually host a PJRT client — lets callers
/// (benches, artifact-gated tests) skip instead of fail.
pub fn pjrt_available() -> bool {
    cfg!(feature = "xla")
}

/// Convenience used by artifact-gated tests: `Some(rt)` only when the
/// runtime exists; logs the skip reason otherwise.
pub fn try_cpu(why: &str) -> Option<Runtime> {
    match Runtime::cpu() {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("[pjrt] skipping {why}: {e}");
            None
        }
    }
}

#[cfg(test)]
mod tests {
    #[cfg(feature = "xla")]
    #[test]
    fn cpu_client_comes_up() {
        let rt = super::Runtime::cpu().unwrap();
        assert_eq!(rt.platform(), "cpu");
    }

    #[cfg(not(feature = "xla"))]
    #[test]
    fn stub_reports_unavailable() {
        assert!(!super::pjrt_available());
        let err = super::Runtime::cpu().err().expect("stub must error");
        assert!(err.to_string().contains("xla"));
    }
}
