//! Artifact path resolution and manifest-driven executable loading.
//!
//! Artifacts live in `artifacts/` (or `$UNIT_ARTIFACTS`):
//!
//! * `<ds>_fwd_b{1,8}.hlo.txt` — inference graphs,
//! * `<ds>_train_b32.hlo.txt` — one SGD+momentum step,
//! * `<ds>_manifest.txt` — parameter ABI,
//! * `weights/<ds>.bin` — trained parameters (written by the trainer).

use anyhow::{Context, Result};
use std::path::PathBuf;

use super::pjrt::{Executable, Runtime};
use crate::models::Manifest;

/// Resolves artifact paths and loads executables with the right shapes.
pub struct ArtifactStore {
    /// Artifact root directory.
    pub dir: PathBuf,
}

impl ArtifactStore {
    /// Default store: `$UNIT_ARTIFACTS` or `./artifacts` (walking up one
    /// level if invoked from a subdirectory, as cargo test/bench do).
    pub fn discover() -> ArtifactStore {
        if let Ok(d) = std::env::var("UNIT_ARTIFACTS") {
            return ArtifactStore { dir: PathBuf::from(d) };
        }
        for cand in ["artifacts", "../artifacts", "../../artifacts"] {
            let p = PathBuf::from(cand);
            if p.is_dir() {
                return ArtifactStore { dir: p };
            }
        }
        ArtifactStore { dir: PathBuf::from("artifacts") }
    }

    /// Load `{model}_manifest.txt` from the store.
    pub fn manifest(&self, model: &str) -> Result<Manifest> {
        Manifest::load(&self.dir.join(format!("{model}_manifest.txt")))
    }

    /// Path of the model's trained-weights binary.
    pub fn weights_path(&self, model: &str) -> PathBuf {
        self.dir.join("weights").join(format!("{model}.bin"))
    }

    /// Path of a named HLO text artifact.
    pub fn hlo_path(&self, name: &str) -> PathBuf {
        self.dir.join(format!("{name}.hlo.txt"))
    }

    /// Load the forward executable at the given batch size.
    /// Args: params… (from manifest), x `(B,C,H,W)`, t_vec `(L,)`, fat_t scalar.
    pub fn load_fwd(&self, rt: &Runtime, model: &str, batch: usize) -> Result<Executable> {
        let m = self.manifest(model)?;
        let mut shapes: Vec<Vec<usize>> = m.params.iter().map(|(_, s)| s.clone()).collect();
        let [c, h, w] = m.input_shape;
        shapes.push(vec![batch, c, h, w]);
        shapes.push(vec![m.prunable]);
        shapes.push(vec![]);
        self.load(rt, &format!("{model}_fwd_b{batch}"), shapes)
    }

    /// Load the train-step executable (batch 32).
    /// Args: params…, momenta…, x `(32,C,H,W)`, y `(32,K)`, lr scalar.
    pub fn load_train(&self, rt: &Runtime, model: &str) -> Result<Executable> {
        let m = self.manifest(model)?;
        let pshapes: Vec<Vec<usize>> = m.params.iter().map(|(_, s)| s.clone()).collect();
        let mut shapes = pshapes.clone();
        shapes.extend(pshapes);
        let [c, h, w] = m.input_shape;
        shapes.push(vec![32, c, h, w]);
        shapes.push(vec![32, m.classes]);
        shapes.push(vec![]);
        self.load(rt, &format!("{model}_train_b32"), shapes)
    }

    fn load(&self, rt: &Runtime, name: &str, shapes: Vec<Vec<usize>>) -> Result<Executable> {
        let path = self.hlo_path(name);
        rt.load_hlo(&path, shapes)
            .with_context(|| format!("loading artifact {name} (run `make artifacts`?)"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::Path;

    #[test]
    fn discover_prefers_env() {
        std::env::set_var("UNIT_ARTIFACTS", "/tmp/somewhere");
        let s = ArtifactStore::discover();
        assert_eq!(s.dir, PathBuf::from("/tmp/somewhere"));
        std::env::remove_var("UNIT_ARTIFACTS");
    }

    #[test]
    fn path_shapes() {
        let s = ArtifactStore { dir: PathBuf::from("/a") };
        assert_eq!(s.hlo_path("mnist_fwd_b1"), Path::new("/a/mnist_fwd_b1.hlo.txt"));
        assert_eq!(s.weights_path("kws"), Path::new("/a/weights/kws.bin"));
    }
}
