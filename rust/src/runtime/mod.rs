//! PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the in-process CPU
//! client via the `xla` crate.
//!
//! This is the only bridge between layers 2/1 (JAX/Pallas, build-time)
//! and layer 3 (Rust, runtime). Python never runs here — the artifacts
//! are plain text files compiled by XLA's C++ at load time.

pub mod artifacts;
pub mod pjrt;

pub use artifacts::ArtifactStore;
pub use pjrt::{pjrt_available, try_cpu, Executable, Runtime};
