//! Minimal, dependency-free subset of the `anyhow` 1.x API.
//!
//! The build image has no crates.io access, so this crate vendors just
//! the surface `unit_pruner` uses: [`Error`], [`Result`], the
//! [`Context`] extension trait, and the `anyhow!` / `bail!` / `ensure!`
//! macros. Semantics match anyhow where it matters:
//!
//! * `Error` is a cheap, `Send + Sync` dynamic error that records a
//!   context chain (`Debug` prints the chain, `Display` prints the
//!   outermost message);
//! * any `std::error::Error + Send + Sync + 'static` converts into it
//!   via `?` (the `From` blanket impl below);
//! * `.context(..)` / `.with_context(..)` wrap `Result` and `Option`.
//!
//! If the real anyhow ever lands in the vendor set, deleting this crate
//! and pointing Cargo.toml at it is a drop-in swap.

use std::fmt::{self, Debug, Display};

/// Dynamic error with a human-readable context chain.
pub struct Error {
    /// Outermost message (most recent context).
    msg: String,
    /// Older messages, outermost-first (the "Caused by" chain).
    chain: Vec<String>,
}

impl Error {
    /// Construct from a printable message.
    pub fn msg<M: Display>(message: M) -> Error {
        Error { msg: message.to_string(), chain: Vec::new() }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: Display>(self, context: C) -> Error {
        let mut chain = Vec::with_capacity(self.chain.len() + 1);
        chain.push(self.msg);
        chain.extend(self.chain);
        Error { msg: context.to_string(), chain }
    }

    /// The context chain, outermost message first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        std::iter::once(self.msg.as_str()).chain(self.chain.iter().map(|s| s.as_str()))
    }

    /// The innermost (root cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or(&self.msg)
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        if !self.chain.is_empty() {
            f.write_str("\n\nCaused by:")?;
            for (i, c) in self.chain.iter().enumerate() {
                write!(f, "\n    {i}: {c}")?;
            }
        }
        Ok(())
    }
}

// Like anyhow, `Error` deliberately does NOT implement std::error::Error
// — that is what makes this blanket conversion coherent.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = Vec::new();
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { msg: e.to_string(), chain }
    }
}

/// `anyhow::Result<T>` — `Result` with [`Error`] as the default error.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)`.
///
/// The extra `E` type parameter keeps the `Result` and `Option` impls
/// from overlapping (same trick as anyhow itself).
pub trait Context<T, E> {
    fn context<C: Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: Into<Error>> Context<T, E> for Result<T, E> {
    fn context<C: Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        match self {
            Ok(v) => Ok(v),
            Err(e) => Err(e.into().context(context)),
        }
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        match self {
            Ok(v) => Ok(v),
            Err(e) => Err(e.into().context(f())),
        }
    }
}

impl<T> Context<T, ()> for Option<T> {
    fn context<C: Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        match self {
            Some(v) => Ok(v),
            None => Err(Error::msg(context)),
        }
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        match self {
            Some(v) => Ok(v),
            None => Err(Error::msg(f())),
        }
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)+) => {
        $crate::Error::msg(format!($($arg)+))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)+) => {
        return ::core::result::Result::Err($crate::anyhow!($($arg)+))
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: `{}`", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            $crate::bail!($($arg)+);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn from_std_error_and_context() {
        let r: Result<()> = Err(io_err().into());
        let r = r.context("opening file");
        let e = r.unwrap_err();
        assert_eq!(e.to_string(), "opening file");
        assert_eq!(e.root_cause(), "gone");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by"));
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing").unwrap_err();
        assert_eq!(e.to_string(), "missing");
        assert_eq!(Some(3).context("missing").unwrap(), 3);
    }

    #[test]
    fn macros() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 5 {
                bail!("five is right out");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(f(12).unwrap_err().to_string(), "x too big: 12");
        assert_eq!(f(5).unwrap_err().to_string(), "five is right out");
        let e = anyhow!("code {}", 7);
        assert_eq!(e.to_string(), "code 7");
    }

    #[test]
    fn question_mark_converts() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(f().unwrap_err().to_string(), "gone");
    }
}
