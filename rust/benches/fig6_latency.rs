//! Fig. 6 — inference runtime (compute + data movement) on the MCU,
//! MNIST / CIFAR10 / KWS, per mechanism, plus the SONIC intermittent-
//! power wall-clock (the paper's battery-free deployment regime).
//!
//! Expected shape: UnIT fastest; data movement a large share of total
//! time (the paper: "most of the time is spent moving data"); KWS ≫
//! CIFAR > MNIST in absolute seconds.

use unit_pruner::mcu::{cost, HarvestProfile, IntermittentSim};
use unit_pruner::report::experiments::{prepare, run_mcu_dataset, MechOpts};
use unit_pruner::report::fig6_table;
use unit_pruner::runtime::{ArtifactStore, Runtime};

fn main() -> anyhow::Result<()> {
    let rt = Runtime::cpu()?;
    let store = ArtifactStore::discover();
    let opts = MechOpts::default();

    println!("=== Fig. 6: inference runtime incl. data movement ===\n");
    for model in ["mnist", "cifar", "kws"] {
        let p = prepare(&rt, &store, model, &opts)?;
        let (_base, rows) = run_mcu_dataset(&p, &opts);
        println!("{}", fig6_table(model, &rows));

        // Intermittent (harvested-power) wall clock: replay each
        // mechanism's cycle budget through the SONIC-like simulator.
        println!("intermittent wall-clock (50ms recharge bursts):");
        for r in &rows {
            let total_cycles = (r.mcu_secs * cost::CPU_HZ) as u64;
            // task granularity: ~64 k cycles per committed task
            let n_tasks = (total_cycles / 64_000).max(1);
            let tasks: Vec<u64> = vec![total_cycles / n_tasks; n_tasks as usize];
            let mut sim = IntermittentSim::new(HarvestProfile::default(), 9);
            let run = sim.run(&tasks);
            println!(
                "  {:14} {:8.2}s wall  ({} failures, {:.1}% re-executed)",
                r.mechanism,
                run.wall_secs,
                run.failures,
                100.0 * run.reexecuted_cycles as f64 / total_cycles.max(1) as f64
            );
        }
        println!();
    }
    Ok(())
}
