//! Ablations for the design choices DESIGN.md calls out (§2.1 / §2.2):
//!
//! 1. **Calibration percentile sweep** — the Fig. 5 trade-off curve:
//!    accuracy vs MACs skipped as the threshold percentile rises.
//! 2. **Layer-wise vs group-wise thresholds** — per-output-channel
//!    refinement (the paper's optional fine-grained mode).
//! 3. **Division estimator accuracy impact** — exact vs shift/tree/mask
//!    thresholds change *which* connections are pruned; how much does
//!    model accuracy move?
//! 4. **Per-inference vs precomputed conv thresholds** — the
//!    compute/memory trade-off the paper notes for conv layers.

use anyhow::Result;
use unit_pruner::approx::DivKind;
use unit_pruner::engine::{infer, EngineConfig, PruneMode, QModel};
use unit_pruner::pruning::{calibrate, calibrate_groups, CalibConfig};
use unit_pruner::report::experiments::{prepare, MechOpts};
use unit_pruner::runtime::{ArtifactStore, Runtime};
use unit_pruner::util::table::Table;

fn main() -> Result<()> {
    let rt = Runtime::cpu()?;
    let store = ArtifactStore::discover();
    let opts = MechOpts::default();
    let model = "mnist";
    let p = prepare(&rt, &store, model, &opts)?;
    let n = p.ds.test.len().min(150);

    let eval = |q: &QModel, cfg: &EngineConfig| -> (f64, f64, f64) {
        let mut hits = 0usize;
        let mut skip = 0f64;
        let mut cycles = 0u64;
        for i in 0..n {
            let out = infer(q, &q.quantize_input(p.ds.test.sample(i)), cfg);
            if out.argmax() == p.ds.test.y[i] {
                hits += 1;
            }
            skip += out.skip_fraction();
            cycles += out.ledger.total_cycles();
        }
        (hits as f64 / n as f64, skip / n as f64, cycles as f64 / n as f64)
    };

    // 1. percentile sweep -------------------------------------------------
    println!("=== Ablation 1: calibration percentile sweep ({model}) ===\n");
    let mut t = Table::new(vec!["percentile", "accuracy", "MACs skipped", "Mcycles/inf"]);
    let div = DivKind::Shift.build();
    for pct in [5.0, 10.0, 20.0, 35.0, 50.0, 70.0] {
        let th = calibrate(
            &p.def,
            &p.params,
            &p.ds.val,
            &CalibConfig { percentile: pct, ..Default::default() },
        );
        let q = QModel::quantize(&p.def, &p.params).with_thresholds(&th);
        let cfg = EngineConfig::unit(div.as_ref());
        let (acc, skip, cyc) = eval(&q, &cfg);
        t.row(vec![
            format!("p{pct:.0}"),
            format!("{:.2}%", 100.0 * acc),
            format!("{:.2}%", 100.0 * skip),
            format!("{:.2}", cyc / 1e6),
        ]);
    }
    println!("{}", t.render());

    // 2. layer vs group thresholds ----------------------------------------
    println!("=== Ablation 2: layer-wise vs group-wise thresholds ===\n");
    let mut t = Table::new(vec!["mode", "accuracy", "MACs skipped", "Mcycles/inf"]);
    let th_layer = calibrate(&p.def, &p.params, &p.ds.val, &CalibConfig::default());
    let th_group = calibrate_groups(&p.def, &p.params, &p.ds.val, &CalibConfig::default());
    for (name, th) in [("layer-wise", &th_layer), ("group-wise", &th_group)] {
        let q = QModel::quantize(&p.def, &p.params).with_thresholds(th);
        let cfg = EngineConfig::unit(div.as_ref());
        let (acc, skip, cyc) = eval(&q, &cfg);
        t.row(vec![
            name.to_string(),
            format!("{:.2}%", 100.0 * acc),
            format!("{:.2}%", 100.0 * skip),
            format!("{:.2}", cyc / 1e6),
        ]);
    }
    println!("{}", t.render());

    // 3. division estimator impact ----------------------------------------
    println!("=== Ablation 3: division estimator impact on accuracy ===\n");
    let mut t = Table::new(vec!["estimator", "accuracy", "MACs skipped", "Mcycles/inf"]);
    let q = QModel::quantize(&p.def, &p.params).with_thresholds(&th_layer);
    for kind in DivKind::all() {
        let d = kind.build();
        let cfg = EngineConfig::unit(d.as_ref());
        let (acc, skip, cyc) = eval(&q, &cfg);
        t.row(vec![
            d.name().to_string(),
            format!("{:.2}%", 100.0 * acc),
            format!("{:.2}%", 100.0 * skip),
            format!("{:.2}", cyc / 1e6),
        ]);
    }
    println!("{}", t.render());

    // 4. per-inference vs precomputed conv thresholds ----------------------
    println!("=== Ablation 4: per-inference vs precomputed conv thresholds ===\n");
    let mut t = Table::new(vec!["variant", "Mcycles/inf", "extra model bytes"]);
    for (name, pre) in [("per-inference divisions", false), ("precomputed table", true)] {
        let cfg = EngineConfig {
            mode: PruneMode::Unit,
            div: div.as_ref(),
            sonic_accumulators: true,
            precomputed_conv_thresholds: pre,
            t_scale_q8: 256,
        };
        let (_acc, _skip, cyc) = eval(&q, &cfg);
        // table cost: one u32 per conv tap
        let bytes: usize = p
            .def
            .layers
            .iter()
            .filter_map(|l| match *l {
                unit_pruner::nn::Layer::Conv { out_ch, in_ch, kh, kw, .. } => {
                    Some(4 * out_ch * in_ch * kh * kw)
                }
                _ => None,
            })
            .sum();
        t.row(vec![
            name.to_string(),
            format!("{:.2}", cyc / 1e6),
            if pre { bytes.to_string() } else { "0".into() },
        ]);
    }
    println!("{}", t.render());
    Ok(())
}
