//! Adaptive control-plane benchmarks: what a scale change costs.
//!
//! Three rows per model, in descending cost:
//!
//! * **full compile** — `PlannedModel::compile` from scratch (sorts
//!   every linear row and every conv segment): what a naive
//!   "recompile on scale change" serving loop would pay per
//!   controller move;
//! * **cut-table stamp** — `compile_shared` against a donor plan
//!   (linear tables *and* conv tap/lane tables reused behind `Arc`s;
//!   only the conv cut tables — stamped `w̄` + `always`/`live`
//!   prefix lengths — and the linear `t_eff` scalars rebuilt): the
//!   plan cache's miss cost, now `n` divisions with **no sorting**;
//! * **cache-hit swap** — `PlanCache::plan_at` on a resident step plus
//!   the `PlanSlot` swap: the steady-state cost of a budget move, which
//!   is what the serve path pays once the grid is warm.
//!
//! The remaining misses don't even run on the serve path: the
//! governor's background compile thread stamps them while the pool
//! serves the nearest resident plan (`benches/perf_hotpath.rs`
//! measures that miss→upgrade latency into `BENCH_perf.json`, section
//! `plan_compile_us`).
//!
//! Standalone observability bench (not part of the `BENCH_perf.json`
//! ratio gate): absolute compile times are machine-dependent. Set
//! `$UNIT_PERF_QUICK` for the CI smoke mode.

use std::sync::Arc;
use std::time::Instant;

use unit_pruner::approx::DivKind;
use unit_pruner::control::{PlanCache, ScaleGrid};
use unit_pruner::coordinator::PlanSlot;
use unit_pruner::engine::{PlanConfig, PlannedModel, QModel};
use unit_pruner::models::{zoo, Params};
use unit_pruner::pruning::Thresholds;
use unit_pruner::util::table::Table;

fn time_us<F: FnMut()>(reps: usize, mut f: F) -> f64 {
    let t0 = Instant::now();
    for _ in 0..reps {
        f();
    }
    t0.elapsed().as_secs_f64() * 1e6 / reps as f64
}

fn main() {
    let quick = std::env::var("UNIT_PERF_QUICK").is_ok();
    if quick {
        println!("(UNIT_PERF_QUICK set: CI smoke mode, reduced repetitions)\n");
    }
    println!("=== Adaptive: plan-swap latency vs full recompile ===\n");

    let models: &[&str] = if quick { &["mnist"] } else { &["mnist", "cifar", "kws"] };
    let mut t = Table::new(vec![
        "model",
        "full compile us",
        "cut-table stamp us",
        "cache-hit swap us",
        "hit speedup",
    ]);
    for &name in models {
        let def = zoo(name);
        let params = Params::random(&def, 5);
        let q = QModel::quantize(&def, &params)
            .with_thresholds(&Thresholds::uniform(def.layers.len(), 0.2));
        let cfg = PlanConfig::unit(DivKind::Shift);
        let grid = ScaleGrid::default_grid();
        let reps = if quick { 3 } else { 10 };

        let donor = PlannedModel::compile(&q, cfg);
        let full_us = time_us(reps, || {
            std::hint::black_box(PlannedModel::compile(
                &q,
                PlanConfig { t_scale_q8: 700, ..cfg },
            ));
        });
        let shared_us = time_us(reps, || {
            std::hint::black_box(PlannedModel::compile_shared(
                &q,
                PlanConfig { t_scale_q8: 700, ..cfg },
                Some(&donor),
            ));
        });

        // Warm two steps, then measure the steady-state swap: cache
        // lookup (hit) + slot swap, alternating steps like an AIMD
        // walk would.
        let cache = PlanCache::new(q.clone(), cfg, grid.clone());
        let slot = PlanSlot::new(Arc::new(PlannedModel::compile(&q, cfg)));
        let (a, b) = (grid.snap_q8(256), grid.snap_q8(512));
        cache.plan_at(a);
        cache.plan_at(b);
        let mut flip = false;
        let hit_reps = if quick { 2_000 } else { 20_000 };
        let hit_us = time_us(hit_reps, || {
            flip = !flip;
            let step = if flip { a } else { b };
            slot.swap(cache.plan_at(step));
        });

        t.row(vec![
            name.to_string(),
            format!("{full_us:.0}"),
            format!("{shared_us:.0}"),
            format!("{hit_us:.2}"),
            format!("{:.0}x", full_us / hit_us.max(1e-9)),
        ]);
    }
    println!("{}", t.render());
    println!(
        "cache-hit swaps are the serve-path steady state: the grid is warmed at calibration\n\
         time, so a budget move costs a lookup + Arc swap, not a recompile."
    );
}
