//! Fig. 7 — average energy per inference (mJ) on the MCU, per mechanism.
//!
//! Expected shape (paper): UnIT lowest (e.g. MNIST 1.28 mJ → 0.20 mJ,
//! −84 %); FATReLU and TTP in between; combining UnIT with FATReLU can
//! help slightly.

use unit_pruner::report::experiments::{prepare, run_mcu_dataset, MechOpts};
use unit_pruner::report::fig7_table;
use unit_pruner::runtime::{ArtifactStore, Runtime};

fn main() -> anyhow::Result<()> {
    let rt = Runtime::cpu()?;
    let store = ArtifactStore::discover();
    let opts = MechOpts::default();

    println!("=== Fig. 7: energy per inference ===\n");
    for model in ["mnist", "cifar", "kws"] {
        let p = prepare(&rt, &store, model, &opts)?;
        let (_base, rows) = run_mcu_dataset(&p, &opts);
        println!("{}", fig7_table(model, &rows));
        let none = rows.iter().find(|r| r.mechanism == "None").unwrap();
        let unit = rows.iter().find(|r| r.mechanism == "UnIT").unwrap();
        println!(
            "UnIT saves {:.1}% energy vs unpruned\n",
            100.0 * (1.0 - unit.energy_mj / none.energy_mj)
        );
    }
    Ok(())
}
