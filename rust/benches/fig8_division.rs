//! Fig. 8 — fast division approximations vs traditional division.
//!
//! (a) MSP430 model: bit shifting and binary tree search vs the software
//!     division routine, in modeled cycles and energy over a calibration-
//!     shaped operand distribution. Paper: 50–59.8 % lower time,
//!     53.7–60.3 % lower energy.
//! (b) Host CPU: the IEEE-754 bit-masking estimator vs hardware f32
//!     division, measured in wall-clock over a large iteration count
//!     (paper: Intel i7, 44.8 % faster). We also report estimator error.

use std::hint::black_box;
use std::time::Instant;

use unit_pruner::approx::{DivApprox, DivExact, DivKind, DivMask, DivShift, DivTree};
use unit_pruner::mcu::EnergyModel;
use unit_pruner::util::table::Table;
use unit_pruner::util::Rng;

/// Operand distribution shaped like real calibration data: thresholds
/// T_raw in the thousands, control terms spanning Q8.8 magnitudes.
fn operands(n: usize, seed: u64) -> Vec<(u32, u32)> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            let t = 500 + rng.below(50_000) as u32;
            let c = 1 + rng.below(32_768) as u32;
            (t, c)
        })
        .collect()
}

fn main() {
    let ops = operands(200_000, 7);
    let energy = EnergyModel::default();

    println!("=== Fig. 8a: modeled MSP430 cycles & energy per division ===\n");
    let mut t = Table::new(vec![
        "method",
        "cycles/op",
        "vs exact",
        "energy nJ/op",
        "mean rel err",
    ]);
    let exact_cycles: u64 = ops.iter().map(|&(a, c)| DivExact.cycles(a, c)).sum();
    for kind in DivKind::all() {
        let d = kind.build();
        let mut cycles = 0u64;
        let mut err = 0f64;
        let mut nerr = 0usize;
        for &(a, c) in &ops {
            cycles += d.cycles(a, c);
            let got = d.div(a, c) as f64;
            let want = (a / c) as f64;
            if want > 0.0 {
                err += (got - want).abs() / want;
                nerr += 1;
            }
        }
        let per = cycles as f64 / ops.len() as f64;
        let nj = energy.millijoules(cycles, 0, 0) * 1e6 / ops.len() as f64;
        t.row(vec![
            d.name().to_string(),
            format!("{per:.1}"),
            format!("{:+.1}%", 100.0 * (cycles as f64 / exact_cycles as f64 - 1.0)),
            format!("{nj:.1}"),
            format!("{:.3}", err / nerr.max(1) as f64),
        ]);
    }
    println!("{}", t.render());

    println!("=== Fig. 8b: host-CPU wall-clock, bit masking vs f32 division ===\n");
    let n = 20_000_000usize;
    let mut rng = Rng::new(11);
    let xs: Vec<f32> = (0..4096).map(|_| 0.01 + rng.f32() * 100.0).collect();
    let ts: Vec<f32> = (0..4096).map(|_| 0.01 + rng.f32() * 100.0).collect();

    let t0 = Instant::now();
    let mut acc = 0f32;
    for i in 0..n {
        let x = xs[i & 4095];
        let tt = ts[(i >> 1) & 4095];
        acc += black_box(tt / x);
    }
    let t_div = t0.elapsed().as_secs_f64();

    let t0 = Instant::now();
    let mut acc2 = 0f32;
    for i in 0..n {
        let x = xs[i & 4095];
        let tt = ts[(i >> 1) & 4095];
        acc2 += black_box(DivMask::div_f32(tt, x));
    }
    let t_mask = t0.elapsed().as_secs_f64();

    println!("f32 division : {:.3}s for {}M ops ({acc:.1})", t_div, n / 1_000_000);
    println!("bit masking  : {:.3}s for {}M ops ({acc2:.1})", t_mask, n / 1_000_000);
    println!(
        "bit masking is {:.1}% {} than hardware division (paper: 44.8% faster on i7)\n",
        100.0 * (1.0 - t_mask / t_div).abs(),
        if t_mask < t_div { "faster" } else { "slower" }
    );

    // Per-method modeled savings summary (the paper's headline band).
    let shift_cycles: u64 = ops.iter().map(|&(a, c)| DivShift.cycles(a, c)).sum();
    let tree_cycles: u64 = ops.iter().map(|&(a, c)| DivTree.cycles(a, c)).sum();
    println!(
        "modeled MSP430 savings: shift {:.1}%, tree {:.1}% (paper band: 50-59.8%)",
        100.0 * (1.0 - shift_cycles as f64 / exact_cycles as f64),
        100.0 * (1.0 - tree_cycles as f64 / exact_cycles as f64)
    );
}
