//! Table 2 — cross-context robustness on Widar: train in one room, test
//! in the other, for {Unpruned, TTP, UnIT, TTP+UnIT}; report macro-F1
//! and MAC-skipped % (float platform, as in the paper).
//!
//! Expected shape: F1 within ~±1–2 % of unpruned across contexts; UnIT
//! skips more MACs than TTP; TTP+UnIT skips the most.

use anyhow::Result;
use unit_pruner::data::widar_like::{generate_room, Room};
use unit_pruner::data::Sizes;
use unit_pruner::models::zoo;
use unit_pruner::nn::ForwardOpts;
use unit_pruner::pruning::{apply_global_magnitude, calibrate, CalibConfig};
use unit_pruner::report::table2;
use unit_pruner::runtime::{ArtifactStore, Runtime};
use unit_pruner::train::{ensure_trained_tagged, evaluate_float, TrainConfig};

fn main() -> Result<()> {
    let rt = Runtime::cpu()?;
    let store = ArtifactStore::discover();
    let def = zoo("widar");
    let sizes = Sizes::default();
    let seed = 42;
    let n_eval = 200;
    let calib = CalibConfig::default();

    let mut rows: Vec<(String, String, String, f64, f64)> = Vec::new();

    for train_room in [Room::Room1, Room::Room2] {
        let ds_train = generate_room(seed, sizes, train_room);
        let params = ensure_trained_tagged(
            &rt,
            &store,
            "widar",
            &format!("widar-{}", train_room.name()),
            &ds_train,
            &TrainConfig::for_model("widar"),
        )?;
        let params_ttp = apply_global_magnitude(&params, 0.5);
        // Thresholds calibrated on the *training context's* validation
        // split — deployment never sees the target context in advance.
        let th = calibrate(&def, &params, &ds_train.val, &calib);
        let th_ttp = calibrate(&def, &params_ttp, &ds_train.val, &calib);

        for test_room in [Room::Room1, Room::Room2] {
            let ds_test = generate_room(seed, sizes, test_room);
            let nl = def.layers.len();
            let mech: [(&str, &_, Vec<f32>); 4] = [
                ("Unpruned", &params, vec![0.0; nl]),
                ("TTP", &params_ttp, vec![0.0; nl]),
                ("UnIT", &params, th.per_layer.clone()),
                ("TTP+UnIT", &params_ttp, th_ttp.per_layer.clone()),
            ];
            for (name, p, t_vec) in mech {
                let r = evaluate_float(
                    &def,
                    p,
                    &ds_test.test,
                    &ForwardOpts { t_vec, fat_t: 0.0 },
                    n_eval,
                );
                rows.push((
                    train_room.name().to_string(),
                    test_room.name().to_string(),
                    name.to_string(),
                    r.macro_f1,
                    r.mac_skipped,
                ));
            }
        }
    }

    println!("=== Table 2: Widar cross-context (train room -> test room) ===\n");
    println!("{}", table2(&rows));

    // Shape checks the paper emphasizes, printed as a summary.
    let get = |tr: &str, te: &str, m: &str| {
        rows.iter()
            .find(|(a, b, c, _, _)| a == tr && b == te && c == m)
            .map(|(_, _, _, f1, sk)| (*f1, *sk))
            .unwrap()
    };
    for (tr, te) in [("room1", "room2"), ("room2", "room1")] {
        let (f1_un, _) = get(tr, te, "Unpruned");
        let (f1_unit, sk_unit) = get(tr, te, "UnIT");
        let (_, sk_ttp) = get(tr, te, "TTP");
        let (_, sk_both) = get(tr, te, "TTP+UnIT");
        println!(
            "{tr}->{te}: UnIT F1 {:+.3} vs unpruned; skips {:.1}% (TTP {:.1}%, TTP+UnIT {:.1}%)",
            f1_unit - f1_un,
            100.0 * sk_unit,
            100.0 * sk_ttp,
            100.0 * sk_both
        );
    }
    Ok(())
}
