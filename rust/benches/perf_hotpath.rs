//! L3 hot-path microbenchmarks (the §Perf harness in EXPERIMENTS.md):
//!
//! * engine throughput — simulated connections per host-second, per
//!   pruning mode, for BOTH backends: the naive reference loops and the
//!   prepacked execution plans (`engine::plan`). The planned Unit path
//!   is the serving hot path; the acceptance bar is ≥ 2× naive Unit.
//! * division estimators — host ns/op;
//! * coordinator overhead — request round-trip latency vs raw engine
//!   call at several worker counts (McuSim workers run the planned
//!   engine on the work-stealing shard pool), with queue wait and
//!   service time reported separately;
//! * batched eval — sequential vs parallel, float
//!   (`evaluate_float_parallel`) and fixed-point
//!   (`evaluate_quant_parallel`).
//!
//! Run before and after each optimization; record deltas in
//! EXPERIMENTS.md §Perf. Alongside the printed tables the same numbers
//! are serialized to `BENCH_perf.json` (override the path with
//! `$UNIT_BENCH_JSON`) so the perf trajectory is machine-readable from
//! this PR onward; `unit bench diff` compares two snapshots and gates
//! CI. Set `$UNIT_PERF_QUICK` for the CI smoke mode (same measurements,
//! fewer repetitions).

use std::hint::black_box;
use std::sync::Arc;
use std::time::Instant;

use unit_pruner::approx::DivKind;
use unit_pruner::control::{Governor, PlanCache, ScaleGrid};
use unit_pruner::coordinator::{BackendChoice, Coordinator, EnergyTap, PlanSlot, ServeConfig};
use unit_pruner::data::{mnist_like, Sizes};
use unit_pruner::engine::{
    infer, EngineConfig, KernelBackend, PlanBacked, PlanConfig, PlannedModel, PruneMode, QModel,
};
use unit_pruner::models::{zoo, ModelDef, Params};
use unit_pruner::nn::Layer;
use unit_pruner::nn::ForwardOpts;
use unit_pruner::pruning::Thresholds;
use unit_pruner::report::bench::{
    BenchPerf, CompileRow, CoordRow, DivRow, EngineRow, EvalRow, LayerRow,
};
use unit_pruner::train::{
    evaluate_float, evaluate_float_parallel, evaluate_quant, evaluate_quant_parallel,
};
use unit_pruner::util::table::Table;

fn main() {
    let quick = std::env::var("UNIT_PERF_QUICK").is_ok();
    if quick {
        println!("(UNIT_PERF_QUICK set: CI smoke mode, reduced repetitions)\n");
    }
    // `--kernel auto|scalar|lanes|simd` (or $UNIT_KERNEL) forces the
    // backend every Auto-configured plan below resolves to — the CI
    // simd-forced leg runs `-- --kernel simd`. The explicit three-way
    // section (1b) pins its own backends and is unaffected.
    let argv: Vec<String> = std::env::args().collect();
    let kernel_arg = argv
        .iter()
        .position(|a| a == "--kernel")
        .and_then(|i| argv.get(i + 1))
        .cloned()
        .or_else(|| argv.iter().find_map(|a| a.strip_prefix("--kernel=").map(String::from)));
    if let Some(s) = kernel_arg {
        match KernelBackend::parse(&s) {
            Some(k) => KernelBackend::set_process_default(k),
            None => {
                eprintln!("unknown --kernel '{s}' (expected auto|scalar|lanes|simd)");
                std::process::exit(2);
            }
        }
    }
    println!(
        "kernel backend: {} (simd level: {})\n",
        KernelBackend::active_label(),
        KernelBackend::simd_level()
    );
    let def = zoo("mnist");
    let params = Params::random(&def, 3);
    let ds = mnist_like::generate(5, Sizes { train: 4, val: 4, test: 32 });
    let th = Thresholds::uniform(3, 0.2);
    let mut json = BenchPerf { model: def.name.clone(), ..Default::default() };
    let total_conn = def.total_dense_macs();

    // 1. engine throughput: naive reference loops vs prepacked plans ------
    println!("=== Perf 1: engine throughput (host-side), naive vs planned ===\n");
    let mut t =
        Table::new(vec!["mode", "backend", "inferences/s", "Mconn/s", "us/inference"]);
    let div = DivKind::Shift.build();
    for (name, mode, with_t) in [
        ("dense", PruneMode::Dense, false),
        ("zero-skip", PruneMode::ZeroSkip, false),
        ("unit", PruneMode::Unit, true),
    ] {
        let mut q = QModel::quantize(&def, &params);
        if with_t {
            q = q.with_thresholds(&th);
        }
        let cfg = EngineConfig {
            mode,
            div: div.as_ref(),
            sonic_accumulators: true,
            precomputed_conv_thresholds: false,
            t_scale_q8: 256,
        };
        let inputs: Vec<Vec<i16>> =
            (0..ds.test.len()).map(|i| q.quantize_input(ds.test.sample(i))).collect();
        let mut planned = PlanBacked::new(&q, PlanConfig::for_mode(mode, DivKind::Shift));

        // Equivalence guard: the two backends must agree bit-for-bit
        // before we compare their clocks.
        let a = infer(&q, &inputs[0], &cfg);
        let b = planned.infer(&inputs[0]);
        assert_eq!(a.logits_raw, b.logits_raw, "{name}: backend logits diverge");
        assert_eq!(a.kept, b.kept, "{name}: backend kept counts diverge");

        // Per-layer MAC accounting for the representative unit-mode
        // inference: section `per_layer_macs` in the snapshot, the
        // offline twin of the serving stack's unit_layer_macs_total /
        // unit_layer_keep_ratio exposition families.
        if mode == PruneMode::Unit {
            for (i, (&k, &s)) in a.kept.iter().zip(&a.skipped).enumerate() {
                json.per_layer.push(LayerRow::new(i, k, s));
            }
        }

        let mut per_backend = Vec::new();
        // Quick mode trims wall-clock but keeps enough reps that the
        // planned-vs-naive ratios (the CI-gated rows) stay stable on a
        // noisy shared runner.
        let (naive_reps, planned_reps) = if quick { (24usize, 96usize) } else { (60, 240) };
        for (backend, reps) in [("naive", naive_reps), ("planned", planned_reps)] {
            // warmup
            if backend == "naive" {
                black_box(infer(&q, &inputs[0], &cfg));
            } else {
                black_box(planned.infer(&inputs[0]));
            }
            let t0 = Instant::now();
            for r in 0..reps {
                let x = &inputs[r % inputs.len()];
                if backend == "naive" {
                    black_box(infer(&q, x, &cfg));
                } else {
                    black_box(planned.infer(x));
                }
            }
            let per = t0.elapsed().as_secs_f64() / reps as f64;
            let row = EngineRow {
                mode: name.to_string(),
                backend: backend.to_string(),
                inf_per_s: 1.0 / per,
                mconn_per_s: total_conn as f64 / per / 1e6,
                us_per_inf: per * 1e6,
            };
            t.row(vec![
                name.to_string(),
                backend.to_string(),
                format!("{:.1}", row.inf_per_s),
                format!("{:.1}", row.mconn_per_s),
                format!("{:.0}", row.us_per_inf),
            ]);
            per_backend.push(row.inf_per_s);
            json.engine.push(row);
        }
        json.speedups.push((name.to_string(), per_backend[1] / per_backend[0]));
    }
    println!("{}", t.render());
    for (mode, s) in &json.speedups {
        println!("planned/{mode} speedup vs naive: {s:.2}x");
    }
    println!();

    // 1b. conv interior kernel: scalar vs lane-packed vs explicit SIMD ------
    // Same plan tables, same cut tables; only the interior-pixel
    // accumulation loop differs. Bit-identical outputs (pinned by the
    // plan tests and the cross-layer property suite); the ratios are
    // the CI-gated payoff of the lane packing and of the intrinsic
    // tile kernel. On hosts with no SIMD level the `simd` leg runs its
    // scalar fallback, so the ratio degrades toward 1.0 instead of
    // failing.
    println!("=== Perf 1b: conv interior kernel, scalar vs lanes vs simd ===\n");
    {
        let q = QModel::quantize(&def, &params).with_thresholds(&th);
        let inputs: Vec<Vec<i16>> =
            (0..ds.test.len()).map(|i| q.quantize_input(ds.test.sample(i))).collect();
        let mut t = Table::new(vec!["interior kernel", "inferences/s", "us/inference"]);
        let reps = if quick { 96usize } else { 400 };
        let mut per_kernel = Vec::new();
        for (label, kernel) in [
            ("scalar", KernelBackend::Scalar),
            ("lanes", KernelBackend::Lanes),
            ("simd", KernelBackend::Simd),
        ] {
            let mut pb = PlanBacked::new(
                &q,
                PlanConfig { kernel, ..PlanConfig::unit(DivKind::Shift) },
            );
            black_box(pb.infer(&inputs[0])); // warmup
            let t0 = Instant::now();
            for r in 0..reps {
                black_box(pb.infer(&inputs[r % inputs.len()]));
            }
            let per = t0.elapsed().as_secs_f64() / reps as f64;
            t.row(vec![
                label.to_string(),
                format!("{:.1}", 1.0 / per),
                format!("{:.0}", per * 1e6),
            ]);
            json.engine.push(EngineRow {
                mode: "unit-conv".to_string(),
                backend: format!("{label}-interior"),
                inf_per_s: 1.0 / per,
                mconn_per_s: total_conn as f64 / per / 1e6,
                us_per_inf: per * 1e6,
            });
            per_kernel.push(1.0 / per);
        }
        json.speedups.push(("conv-lane".to_string(), per_kernel[1] / per_kernel[0]));
        json.speedups.push(("simd-interior".to_string(), per_kernel[2] / per_kernel[0]));
        println!("{}", t.render());
        println!("lane/scalar interior speedup: {:.2}x", per_kernel[1] / per_kernel[0]);
        println!("simd/scalar interior speedup: {:.2}x\n", per_kernel[2] / per_kernel[0]);
    }

    // 1b2. linear row kernel: row-at-a-time vs register-blocked -------------
    // A linear-dominated model so the row kernel is the hot loop: the
    // blocked path gathers 4 live rows per tile (one Eq. 2 prefix
    // lookup each, performed at gather time) and drains the tile with
    // the MAC sweeps fused. Bit-identical outputs; the ratio is the
    // CI-gated payoff of the blocking.
    println!("=== Perf 1b2: linear row kernel, scalar rows vs blocked tiles ===\n");
    {
        let lin_def = ModelDef {
            name: "linear-bench".into(),
            input_shape: [1, 16, 16],
            classes: 10,
            layers: vec![
                Layer::Linear { n_in: 256, n_out: 512, relu: true },
                Layer::Linear { n_in: 512, n_out: 10, relu: false },
            ],
        };
        let lin_params = Params::random(&lin_def, 7);
        let lin_th = Thresholds::uniform(lin_def.layers.len(), 0.2);
        let lq = QModel::quantize(&lin_def, &lin_params).with_thresholds(&lin_th);
        let lin_conn = lin_def.total_dense_macs();
        // Mixed-density inputs: mostly live values with a sprinkle of
        // zeros, so both the row-skip and the Eq. 2 cut paths run.
        let inputs: Vec<Vec<i16>> = (0..16)
            .map(|s| {
                lq.quantize_input(
                    &(0..lin_def.input_len())
                        .map(|i| {
                            if (i + s) % 5 == 0 {
                                0.0
                            } else {
                                (((i * 17 + s * 3) % 31) as f32 - 15.0) / 9.0
                            }
                        })
                        .collect::<Vec<f32>>(),
                )
            })
            .collect();
        let mut t = Table::new(vec!["linear kernel", "inferences/s", "us/inference"]);
        let reps = if quick { 192usize } else { 800 };
        let mut per_kernel = Vec::new();
        for (label, kernel) in
            [("scalar-rows", KernelBackend::Scalar), ("blocked-rows", KernelBackend::Simd)]
        {
            let mut pb = PlanBacked::new(
                &lq,
                PlanConfig { kernel, ..PlanConfig::unit(DivKind::Shift) },
            );
            black_box(pb.infer(&inputs[0])); // warmup
            let t0 = Instant::now();
            for r in 0..reps {
                black_box(pb.infer(&inputs[r % inputs.len()]));
            }
            let per = t0.elapsed().as_secs_f64() / reps as f64;
            t.row(vec![
                label.to_string(),
                format!("{:.1}", 1.0 / per),
                format!("{:.0}", per * 1e6),
            ]);
            json.engine.push(EngineRow {
                mode: "unit-linear".to_string(),
                backend: label.to_string(),
                inf_per_s: 1.0 / per,
                mconn_per_s: lin_conn as f64 / per / 1e6,
                us_per_inf: per * 1e6,
            });
            per_kernel.push(1.0 / per);
        }
        json.speedups.push(("linear-block".to_string(), per_kernel[1] / per_kernel[0]));
        println!("{}", t.render());
        println!("blocked/scalar linear speedup: {:.2}x\n", per_kernel[1] / per_kernel[0]);
    }

    // 1c. scale-change latency tiers ----------------------------------------
    // What a plan-cache miss costs at each tier of the scale-indexed
    // layout: a from-scratch compile, a cut-table stamp over shared
    // tables, a warm cache-hit swap, and a governor background
    // miss→upgrade (the serve path's worst case — which no longer runs
    // on a worker thread).
    println!("=== Perf 1c: scale-change latency (full / stamp / hit / bg upgrade) ===\n");
    {
        let q = QModel::quantize(&def, &params).with_thresholds(&th);
        let cfg = PlanConfig::unit(DivKind::Shift);
        let grid = ScaleGrid::default_grid();
        let reps = if quick { 3 } else { 12 };
        let donor = PlannedModel::compile(&q, cfg);
        let t0 = Instant::now();
        for _ in 0..reps {
            black_box(PlannedModel::compile(&q, PlanConfig { t_scale_q8: 700, ..cfg }));
        }
        let full_us = t0.elapsed().as_secs_f64() * 1e6 / reps as f64;
        let t0 = Instant::now();
        for _ in 0..reps {
            black_box(PlannedModel::compile_shared(
                &q,
                PlanConfig { t_scale_q8: 700, ..cfg },
                Some(&donor),
            ));
        }
        let stamp_us = t0.elapsed().as_secs_f64() * 1e6 / reps as f64;

        // Warm hit + slot swap, alternating two steps.
        let cache = PlanCache::new(q.clone(), cfg, grid.clone());
        let slot = PlanSlot::new(Arc::new(PlannedModel::compile(&q, cfg)));
        let (a, b) = (grid.snap_q8(256), grid.snap_q8(512));
        cache.plan_at(a);
        cache.plan_at(b);
        let hit_reps = if quick { 2_000 } else { 20_000 };
        let mut flip = false;
        let t0 = Instant::now();
        for _ in 0..hit_reps {
            flip = !flip;
            slot.swap(cache.plan_at(if flip { a } else { b }));
        }
        let hit_us = t0.elapsed().as_secs_f64() * 1e6 / hit_reps as f64;

        // Background miss→upgrade: starve a cold governor, time from
        // the first pending compile to the slot landing on the wanted
        // step (observations stop once the miss is queued, so the
        // upgrade is the only mover).
        let coord = Coordinator::start(
            BackendChoice::McuSim { q: q.clone(), mode: PruneMode::Unit, div: DivKind::Shift },
            ServeConfig { workers: 1, ..Default::default() },
        );
        let cold = Arc::new(PlanCache::new(q.clone(), cfg, grid.clone()));
        let gov = Governor::install(&coord, Arc::clone(&cold), None, 1e9).unwrap();
        gov.set_budget(1e-9);
        let upgrade_reps = if quick { 3usize } else { 8 };
        let mut upgrade_total = 0.0f64;
        let mut upgrades = 0usize;
        for _ in 0..upgrade_reps {
            while gov.status().bg_pending == 0 && gov.step() + 1 < grid.len() {
                gov.observe(1e9);
            }
            if gov.status().bg_pending == 0 {
                break; // grid exhausted
            }
            let want = grid.snap_q8(gov.status().scale_q8);
            let t0 = Instant::now();
            let mut timed_out = false;
            while gov.step() != want {
                if t0.elapsed().as_secs() > 30 {
                    timed_out = true; // never wedge CI on a lost upgrade
                    break;
                }
                std::hint::spin_loop();
            }
            if timed_out {
                break;
            }
            upgrade_total += t0.elapsed().as_secs_f64() * 1e6;
            upgrades += 1;
        }
        coord.shutdown();
        let upgrade_us = if upgrades > 0 { upgrade_total / upgrades as f64 } else { 0.0 };

        let mut t = Table::new(vec!["tier", "us"]);
        for (label, us) in [
            ("conv-full-compile", full_us),
            ("conv-cut-stamp", stamp_us),
            ("cache-hit-swap", hit_us),
            ("bg-miss-upgrade", upgrade_us),
        ] {
            t.row(vec![label.to_string(), format!("{us:.1}")]);
            json.compile.push(CompileRow { label: label.to_string(), us });
        }
        println!("{}", t.render());
        println!(
            "stamp/full: {:.2}x cheaper; a warm budget move costs a lookup + Arc swap\n",
            full_us / stamp_us.max(1e-9)
        );
    }

    // 2. division estimators (host ns/op) ----------------------------------
    println!("=== Perf 2: division estimators, host ns/op ===\n");
    let mut t = Table::new(vec!["estimator", "ns/op"]);
    let n = if quick { 3_000_000usize } else { 30_000_000 };
    for kind in DivKind::all() {
        let d = kind.build();
        let t0 = Instant::now();
        let mut acc = 0u64;
        for i in 0..n {
            let tt = (i as u32).wrapping_mul(2_654_435_761) | 1;
            let c = ((i as u32) >> 7) | 1;
            acc = acc.wrapping_add(d.div(tt & 0xFFFFF, c & 0x7FFF) as u64);
        }
        let ns = t0.elapsed().as_nanos() as f64 / n as f64;
        black_box(acc);
        t.row(vec![d.name().to_string(), format!("{ns:.2}")]);
        json.divs.push(DivRow { name: d.name().to_string(), ns_per_op: ns });
    }
    println!("{}", t.render());

    // 3. coordinator overhead ----------------------------------------------
    // Work-stealing shard pool: req/s should scale with the worker
    // count; queue vs service percentiles expose shard imbalance.
    println!("=== Perf 3: coordinator round-trip overhead (work-stealing pool) ===\n");
    let mut t = Table::new(vec![
        "workers", "req/s", "p50 us", "p99 us", "queue p50/p99", "service p50/p99",
    ]);
    let n_req = if quick { 64usize } else { 200 };
    for workers in [1usize, 2, 4] {
        let q = QModel::quantize(&def, &params).with_thresholds(&th);
        let coord = Coordinator::start(
            BackendChoice::McuSim { q, mode: PruneMode::Unit, div: DivKind::Shift },
            ServeConfig { workers, ..Default::default() },
        );
        let t0 = Instant::now();
        // Mixed intake, as production traffic would be: one large
        // batched request split across shards, then a single-request
        // flood.
        let n_batch = n_req / 4;
        let batch_rx = coord.submit_batch(
            (0..n_batch).map(|i| ds.test.sample(i % ds.test.len()).to_vec()).collect(),
        );
        let rxs: Vec<_> = (0..n_req - n_batch)
            .map(|i| coord.submit(ds.test.sample(i % ds.test.len()).to_vec()))
            .collect();
        assert_eq!(batch_rx.recv().unwrap().len(), n_batch);
        for rx in rxs {
            rx.recv().unwrap();
        }
        let dt = t0.elapsed().as_secs_f64();
        let snap = coord.metrics.snapshot();
        coord.shutdown();
        t.row(vec![
            workers.to_string(),
            format!("{:.1}", n_req as f64 / dt),
            snap.p50_us.to_string(),
            snap.p99_us.to_string(),
            format!("{}/{}", snap.queue_p50_us, snap.queue_p99_us),
            format!("{}/{}", snap.service_p50_us, snap.service_p99_us),
        ]);
        json.coord.push(CoordRow {
            workers,
            req_per_s: n_req as f64 / dt,
            p50_us: snap.p50_us,
            p99_us: snap.p99_us,
            queue_p50_us: snap.queue_p50_us,
            queue_p99_us: snap.queue_p99_us,
            service_p50_us: snap.service_p50_us,
            service_p99_us: snap.service_p99_us,
        });
    }
    println!("{}", t.render());

    // 4. batched eval: sequential vs parallel, float + fixed-point ----------
    println!("=== Perf 4: batched eval (samples/s) ===\n");
    let mut t = Table::new(vec!["eval", "samples/s"]);
    let eval_n = if quick { 48 } else { 128 };
    let eval_ds = mnist_like::generate(9, Sizes { train: 4, val: 4, test: eval_n });
    let opts = ForwardOpts::unit(th.per_layer.clone());
    let n_eval = eval_ds.test.len();
    for (label, threads) in [("sequential", usize::MAX), ("parallel-2", 2), ("parallel-auto", 0)]
    {
        let t0 = Instant::now();
        let r = if threads == usize::MAX {
            evaluate_float(&def, &params, &eval_ds.test, &opts, n_eval)
        } else {
            evaluate_float_parallel(&def, &params, &eval_ds.test, &opts, n_eval, threads)
        };
        let dt = t0.elapsed().as_secs_f64();
        black_box(r.accuracy);
        let sps = n_eval as f64 / dt;
        t.row(vec![label.to_string(), format!("{sps:.1}")]);
        json.eval.push(EvalRow { label: label.to_string(), samples_per_s: sps });
    }
    // Fixed-point twin: the Fig. 5–7 sweep hot path. Equivalence guard
    // first (bit-identical parallel vs sequential), then the clocks.
    let qe = QModel::quantize(&def, &params).with_thresholds(&th);
    let qcfg = PlanConfig::for_mode(PruneMode::Unit, DivKind::Shift);
    {
        let seq = evaluate_quant(&qe, qcfg, &eval_ds.test, n_eval);
        let par = evaluate_quant_parallel(&qe, qcfg, &eval_ds.test, n_eval, 0);
        assert_eq!(seq.preds, par.preds, "quant eval: parallel preds diverge");
        assert_eq!(seq.ledger, par.ledger, "quant eval: parallel ledger diverges");
    }
    for (label, threads) in [("quant-sequential", usize::MAX), ("quant-parallel-auto", 0)] {
        let t0 = Instant::now();
        let r = if threads == usize::MAX {
            evaluate_quant(&qe, qcfg, &eval_ds.test, n_eval)
        } else {
            evaluate_quant_parallel(&qe, qcfg, &eval_ds.test, n_eval, threads)
        };
        let dt = t0.elapsed().as_secs_f64();
        black_box(r.accuracy);
        let sps = n_eval as f64 / dt;
        t.row(vec![label.to_string(), format!("{sps:.1}")]);
        json.eval.push(EvalRow { label: label.to_string(), samples_per_s: sps });
    }
    println!("{}", t.render());

    // machine-readable trajectory ------------------------------------------
    let path = std::env::var("UNIT_BENCH_JSON").unwrap_or_else(|_| "BENCH_perf.json".into());
    match json.write(std::path::Path::new(&path)) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("failed to write {path}: {e}"),
    }
}
