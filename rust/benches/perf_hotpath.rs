//! L3 hot-path microbenchmarks (the §Perf harness in EXPERIMENTS.md):
//!
//! * engine throughput — simulated connections per host-second, per
//!   pruning mode (the inner-loop cost of the whole simulator);
//! * division estimators — host ns/op;
//! * coordinator overhead — request round-trip latency vs raw engine
//!   call at several worker counts.
//!
//! Run before and after each optimization; record deltas in
//! EXPERIMENTS.md §Perf.

use std::hint::black_box;
use std::time::Instant;

use unit_pruner::approx::DivKind;
use unit_pruner::coordinator::{BackendChoice, Coordinator, ServeConfig};
use unit_pruner::data::{mnist_like, Sizes};
use unit_pruner::engine::{infer, EngineConfig, PruneMode, QModel};
use unit_pruner::models::{zoo, Params};
use unit_pruner::pruning::Thresholds;
use unit_pruner::util::table::Table;

fn main() {
    let def = zoo("mnist");
    let params = Params::random(&def, 3);
    let ds = mnist_like::generate(5, Sizes { train: 4, val: 4, test: 32 });
    let th = Thresholds::uniform(3, 0.2);

    // 1. engine throughput -------------------------------------------------
    println!("=== Perf 1: engine throughput (host-side) ===\n");
    let mut t = Table::new(vec!["mode", "inferences/s", "Mconn/s", "us/inference"]);
    let div = DivKind::Shift.build();
    let total_conn = def.total_dense_macs();
    for (name, mode, with_t) in [
        ("dense", PruneMode::Dense, false),
        ("zero-skip", PruneMode::ZeroSkip, false),
        ("unit", PruneMode::Unit, true),
    ] {
        let mut q = QModel::quantize(&def, &params);
        if with_t {
            q = q.with_thresholds(&th);
        }
        let cfg = EngineConfig {
            mode,
            div: div.as_ref(),
            sonic_accumulators: true,
            precomputed_conv_thresholds: false,
            t_scale_q8: 256,
        };
        let inputs: Vec<Vec<i16>> =
            (0..ds.test.len()).map(|i| q.quantize_input(ds.test.sample(i))).collect();
        // warmup
        black_box(infer(&q, &inputs[0], &cfg));
        let reps = 60usize;
        let t0 = Instant::now();
        for r in 0..reps {
            black_box(infer(&q, &inputs[r % inputs.len()], &cfg));
        }
        let dt = t0.elapsed().as_secs_f64();
        let per = dt / reps as f64;
        t.row(vec![
            name.to_string(),
            format!("{:.1}", 1.0 / per),
            format!("{:.1}", total_conn as f64 / per / 1e6),
            format!("{:.0}", per * 1e6),
        ]);
    }
    println!("{}", t.render());

    // 2. division estimators (host ns/op) ----------------------------------
    println!("=== Perf 2: division estimators, host ns/op ===\n");
    let mut t = Table::new(vec!["estimator", "ns/op"]);
    let n = 30_000_000usize;
    for kind in DivKind::all() {
        let d = kind.build();
        let t0 = Instant::now();
        let mut acc = 0u64;
        for i in 0..n {
            let tt = (i as u32).wrapping_mul(2_654_435_761) | 1;
            let c = ((i as u32) >> 7) | 1;
            acc = acc.wrapping_add(d.div(tt & 0xFFFFF, c & 0x7FFF) as u64);
        }
        let ns = t0.elapsed().as_nanos() as f64 / n as f64;
        black_box(acc);
        t.row(vec![d.name().to_string(), format!("{ns:.2}")]);
    }
    println!("{}", t.render());

    // 3. coordinator overhead ----------------------------------------------
    println!("=== Perf 3: coordinator round-trip overhead ===\n");
    let mut t = Table::new(vec!["workers", "req/s", "p50 us", "p99 us"]);
    for workers in [1usize, 2, 4] {
        let q = QModel::quantize(&def, &params).with_thresholds(&th);
        let coord = Coordinator::start(
            BackendChoice::McuSim { q, mode: PruneMode::Unit, div: DivKind::Shift },
            ServeConfig { workers, ..Default::default() },
        );
        let n_req = 200usize;
        let t0 = Instant::now();
        let rxs: Vec<_> = (0..n_req)
            .map(|i| coord.submit(ds.test.sample(i % ds.test.len()).to_vec()))
            .collect();
        for rx in rxs {
            rx.recv().unwrap();
        }
        let dt = t0.elapsed().as_secs_f64();
        let snap = coord.metrics.snapshot();
        coord.shutdown();
        t.row(vec![
            workers.to_string(),
            format!("{:.1}", n_req as f64 / dt),
            snap.p50_us.to_string(),
            snap.p99_us.to_string(),
        ]);
    }
    println!("{}", t.render());
}
