//! Streamed-serving benchmarks: wire-codec throughput and loopback
//! end-to-end request rate.
//!
//! * **codec** — encode/decode rate of request and response frames in
//!   memory (the pure `serve::wire` layer): frames/s and MB/s. This is
//!   the per-frame CPU tax every streamed request pays on top of
//!   inference.
//! * **loopback e2e** — a full in-process `Server` on 127.0.0.1 driven
//!   by N concurrent clients submitting batches; reports samples/s and
//!   the server-side queue/service split. Placement is the default
//!   cost-weighted policy, so this is also the end-to-end smoke for
//!   MAC-estimate admission.
//!
//! Standalone observability bench (not part of the `BENCH_perf.json`
//! ratio gate): absolute socket throughput is too machine- and
//! loopback-dependent to gate on. Set `$UNIT_PERF_QUICK` for the CI
//! smoke mode.

use std::hint::black_box;
use std::time::{Duration, Instant};

use unit_pruner::approx::DivKind;
use unit_pruner::coordinator::{BackendChoice, Coordinator, ServeConfig};
use unit_pruner::data::{mnist_like, Sizes};
use unit_pruner::engine::{PruneMode, QModel};
use unit_pruner::models::{zoo, Params};
use unit_pruner::pruning::Thresholds;
use unit_pruner::serve::{wire, Client, Frame, Payload, ServeOpts, Server, Status};
use unit_pruner::util::table::Table;

fn main() {
    let quick = std::env::var("UNIT_PERF_QUICK").is_ok();
    if quick {
        println!("(UNIT_PERF_QUICK set: CI smoke mode, reduced repetitions)\n");
    }

    // 1. codec throughput --------------------------------------------------
    println!("=== Serve 1: wire codec throughput (in-memory) ===\n");
    let mut t = Table::new(vec!["frame", "bytes", "enc frames/s", "dec frames/s", "dec MB/s"]);
    let reps = if quick { 20_000 } else { 200_000 };
    let request = Frame::Request {
        id: 7,
        deadline_ms: 100,
        sample_len: 784,
        model: 0,
        data: Payload::F32((0..784).map(|i| (i % 17) as f32 / 16.0).collect()),
    };
    let response = Frame::Response {
        id: 7,
        slot: 3,
        status: Status::Ok,
        predicted: 4,
        queue_us: 120,
        service_us: 900,
        mac_skipped: 0.8,
        logits: (0..10).map(|i| i as f32 / 10.0).collect(),
    };
    for (name, frame) in [("request(784 f32)", &request), ("response(10 logits)", &response)] {
        let bytes = wire::encode(frame);
        let t0 = Instant::now();
        for _ in 0..reps {
            black_box(wire::encode(black_box(frame)));
        }
        let enc_s = reps as f64 / t0.elapsed().as_secs_f64();
        let t0 = Instant::now();
        for _ in 0..reps {
            black_box(wire::decode(black_box(&bytes)).unwrap().unwrap());
        }
        let dt = t0.elapsed().as_secs_f64();
        let dec_s = reps as f64 / dt;
        t.row(vec![
            name.to_string(),
            bytes.len().to_string(),
            format!("{enc_s:.0}"),
            format!("{dec_s:.0}"),
            format!("{:.1}", reps as f64 * bytes.len() as f64 / dt / 1e6),
        ]);
    }
    println!("{}", t.render());

    // 2. loopback end-to-end ----------------------------------------------
    println!("=== Serve 2: loopback streamed serving (end-to-end) ===\n");
    let def = zoo("mnist");
    let params = Params::random(&def, 11);
    let ds = mnist_like::generate(6, Sizes { train: 4, val: 4, test: 32 });
    let q = QModel::quantize(&def, &params).with_thresholds(&Thresholds::uniform(3, 0.2));
    let mut t = Table::new(vec![
        "clients", "samples", "samples/s", "queue p50 us", "service p50 us", "p99 us",
    ]);
    let client_counts: &[usize] = if quick { &[2] } else { &[1, 2, 4] };
    for &n_clients in client_counts {
        let coord = Coordinator::start(
            BackendChoice::McuSim {
                q: q.clone(),
                mode: PruneMode::Unit,
                div: DivKind::Shift,
            },
            ServeConfig { workers: 4, ..Default::default() },
        );
        let server = Server::start(
            coord,
            "127.0.0.1:0",
            ServeOpts { max_conns: n_clients + 1, ..Default::default() },
        )
        .expect("bind loopback");
        let addr = server.local_addr();
        let per_client = if quick { 48 } else { 192 };
        let batch = 8usize;
        let t0 = Instant::now();
        let handles: Vec<_> = (0..n_clients)
            .map(|_| {
                let samples: Vec<Vec<f32>> =
                    (0..ds.test.len()).map(|i| ds.test.sample(i).to_vec()).collect();
                std::thread::spawn(move || {
                    let client = Client::connect(addr).expect("connect");
                    let mut got = 0usize;
                    for r in 0..per_client / batch {
                        let xs: Vec<Vec<f32>> = (0..batch)
                            .map(|j| samples[(r * batch + j) % samples.len()].clone())
                            .collect();
                        let (_id, rx) = client.submit_batch(&xs, None).expect("submit");
                        for _ in 0..batch {
                            let ev = rx.recv_timeout(Duration::from_secs(60)).expect("reply");
                            assert_eq!(ev.status, Status::Ok);
                            got += 1;
                        }
                    }
                    client.goodbye(Duration::from_secs(5));
                    got
                })
            })
            .collect();
        let total: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
        let dt = t0.elapsed().as_secs_f64();
        let snap = server.metrics().snapshot();
        server.shutdown();
        t.row(vec![
            n_clients.to_string(),
            total.to_string(),
            format!("{:.0}", total as f64 / dt),
            snap.queue_p50_us.to_string(),
            snap.service_p50_us.to_string(),
            snap.p99_us.to_string(),
        ]);
    }
    println!("{}", t.render());
}
