//! Fig. 5 — accuracy drop vs remaining MAC operations, all four
//! datasets × {None, TTP, FATReLU, UnIT, UnIT+FATReLU, TTP+UnIT}.
//!
//! mnist/cifar/kws run on the MCU simulator (the paper's MSP430
//! deployment); widar runs on the float engine (the paper's desktop
//! platform). Models are trained once via the AOT train-step artifact
//! and cached under `artifacts/weights/`.
//!
//! Expected shape (paper §4.1): UnIT skips the most MACs at comparable
//! accuracy; combining with FATReLU adds little; TTP skips less for the
//! same accuracy budget.

use unit_pruner::report::experiments::{prepare, run_float_dataset, run_mcu_dataset, MechOpts};
use unit_pruner::report::fig5_table;
use unit_pruner::runtime::{ArtifactStore, Runtime};

fn main() -> anyhow::Result<()> {
    let rt = Runtime::cpu()?;
    let store = ArtifactStore::discover();
    let opts = MechOpts::default();

    println!("=== Fig. 5: accuracy drop vs remaining MACs ===\n");
    for model in ["mnist", "cifar", "kws", "widar"] {
        let p = prepare(&rt, &store, model, &opts)?;
        let (base, rows) = if model == "widar" {
            run_float_dataset(&p, &opts)
        } else {
            run_mcu_dataset(&p, &opts)
        };
        println!("{}", fig5_table(model, base, &rows));
        // paper-style headline deltas
        let by = |n: &str| rows.iter().find(|r| r.mechanism == n).unwrap();
        let unit = by("UnIT");
        let ttp = by("TTP");
        let fat = by("FATReLU");
        println!(
            "UnIT vs TTP: {:+.2}% MACs skipped, {:+.2}% accuracy",
            100.0 * (unit.mac_skipped - ttp.mac_skipped),
            100.0 * (unit.accuracy - ttp.accuracy)
        );
        println!(
            "UnIT vs FATReLU: {:+.2}% MACs skipped, {:+.2}% accuracy\n",
            100.0 * (unit.mac_skipped - fat.mac_skipped),
            100.0 * (unit.accuracy - fat.accuracy)
        );
    }
    Ok(())
}
