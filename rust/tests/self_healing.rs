//! Self-healing acceptance tests (ISSUE 6):
//!
//! * **domain shift** — a governor calibrated on kws-derived traffic
//!   serves a sudden flip to widar-derived traffic: the drift tracker
//!   trips within a bounded number of observations and the background
//!   recalibration re-measures the keep profile from the reservoir of
//!   recent inputs; the expectation walks to the new distribution
//!   (possibly via one intermediate mixed-reservoir profile, since the
//!   reservoir is only cleared on publish) and, once inside the
//!   tracker's slack, stops tripping — all while every request
//!   completes `Ok`;
//! * **chaos soak** — a loopback server with a seeded fault plan
//!   (injected worker panics, corrupted reply frames, delays, read
//!   stalls) driven by retrying clients: every request still lands
//!   with complete, slot-ordered results, panicked workers are
//!   respawned (counted), and shutdown stays clean.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use unit_pruner::approx::DivKind;
use unit_pruner::control::{DriftCfg, Governor, KeepProfile, PlanCache, ScaleGrid};
use unit_pruner::coordinator::{BackendChoice, Coordinator, ServeConfig};
use unit_pruner::data::{by_name, Sizes};
use unit_pruner::engine::{PlanConfig, PruneMode, QModel};
use unit_pruner::models::{zoo, Params};
use unit_pruner::obs::{EventKind, ObsConfig};
use unit_pruner::pruning::Thresholds;
use unit_pruner::serve::{RetryCfg, RetryClient, ServeOpts, Server, Status};
use unit_pruner::util::fault::SITES;
use unit_pruner::util::{FaultPlan, FaultRates};

fn setup_q(seed: u64) -> QModel {
    let def = zoo("mnist");
    let params = Params::random(&def, seed);
    QModel::quantize(&def, &params).with_thresholds(&Thresholds::uniform(3, 0.15))
}

/// First `len` values of a longer sample: kws (9920) and widar (3718)
/// features reshaped onto the mnist-architecture input so one model
/// can serve both "domains".
fn truncate(sample: &[f32], len: usize) -> Vec<f32> {
    sample[..len].to_vec()
}

/// The ISSUE 6 drift acceptance test: kws→widar distribution flip
/// mid-run re-converges profile and pricing within bounded batches.
///
/// Single-sample phases keep it deterministic: the profile is measured
/// on exactly the streamed input, so the stationary phase's residual
/// is ~0 (no false trips possible) and the shifted phase's residual is
/// a fixed, pre-verified gap (a trip is guaranteed once the CUSUM
/// warmup is past). Convergence is asserted against the tracker's
/// slack: any published expectation farther than the slack from the
/// live distribution keeps tripping and recalibrating (the reservoir
/// holds only shifted inputs after the first publish clears it), so
/// within-slack is the unique fixed point.
#[test]
fn domain_shift_recalibrates_live_and_reconverges() {
    let q = setup_q(71);
    let coord = Coordinator::start(
        BackendChoice::McuSim { q: q.clone(), mode: PruneMode::Unit, div: DivKind::Exact },
        ServeConfig { workers: 2, ..Default::default() },
    );
    let cache = Arc::new(PlanCache::new(
        q,
        PlanConfig::unit(DivKind::Exact),
        ScaleGrid::default_grid(),
    ));
    let input_len = zoo("mnist").input_len();
    let kws = by_name("kws", 9, Sizes { train: 2, val: 2, test: 2 });
    let x_kws = truncate(kws.val.sample(0), input_len);
    let profile = Arc::new(KeepProfile::measure(&cache, &[x_kws.clone()]));
    // Effectively infinite budget: the controller pins the scale at its
    // seeded step, so drift — not budget pressure — is the only thing
    // that can move the control plane during this test.
    let g = Governor::install(&coord, Arc::clone(&cache), Some(Arc::clone(&profile)), 1e9)
        .expect("governor installs on mcu backend");
    let step = g.status().step;
    let expected = profile.model_keep_ratio(step);

    let submit_ok = |x: &[f32]| {
        let rx = coord.submit(x.to_vec());
        rx.recv_timeout(Duration::from_secs(60)).expect("request lost");
    };
    let expectation = || {
        let p = g.profile().expect("profile uninstalled during recalibration");
        p.model_keep_ratio(g.status().step)
    };

    // Phase 1 — stationary kws traffic: enough observations to clear
    // the tracker's warmup, zero trips.
    for _ in 0..48 {
        submit_ok(&x_kws);
    }
    let s = g.status();
    assert_eq!(s.drift_trips, 0, "stationary traffic tripped the drift tracker");
    assert_eq!(s.recalibrations, 0);

    // Phase 2 — flip to widar-derived traffic. Amplitudes are searched
    // so the shifted input's true keep ratio diverges from the
    // kws-calibrated expectation by ≥ 0.1 (input-dependent pruning
    // guarantees the extremes bracket any calibrated value).
    let widar = by_name("widar", 9, Sizes { train: 2, val: 2, test: 2 });
    let base = truncate(widar.val.sample(0), input_len);
    let plan = cache.plan_at(step);
    let mut scratch = plan.new_scratch();
    let shifted: Vec<f32> = [1.0f32, 3.0, 0.3, 8.0, 0.05]
        .iter()
        .find_map(|&amp| {
            let x: Vec<f32> = base.iter().map(|v| v * amp).collect();
            let out = plan.infer(&plan.quantize_input(&x), &mut scratch);
            let keep = 1.0 - out.skip_fraction();
            ((keep - expected).abs() >= 0.1).then_some(x)
        })
        .expect("no amplitude of the widar input diverged from the kws-calibrated keep ratio");
    let shifted_keep = {
        let out = plan.infer(&plan.quantize_input(&shifted), &mut scratch);
        1.0 - out.skip_fraction()
    };
    let slack = DriftCfg::default().slack;

    // Drive shifted batches until the published expectation parks
    // within the tracker's slack of the live distribution. The CUSUM
    // needs ~λ/(|residual|−slack) observations past its warmup per
    // trip, and at most two trip→recalibrate cycles are ever required
    // (the second always measures a pure-shifted reservoir), so the
    // bound is generous.
    let mut converged = false;
    'drive: for _ in 0..150 {
        for _ in 0..8 {
            submit_ok(&shifted);
        }
        let s = g.status();
        if s.recalibrations >= 1 && (expectation() - shifted_keep).abs() <= slack {
            converged = true;
            break 'drive;
        }
    }
    // A trip near the end of the loop may still have its recalibration
    // in flight on the background thread — give it time to land.
    let t0 = Instant::now();
    while !converged && t0.elapsed() < Duration::from_secs(60) {
        let s = g.status();
        converged = s.recalibrations >= 1 && (expectation() - shifted_keep).abs() <= slack;
        std::thread::sleep(Duration::from_millis(20));
    }
    let s = g.status();
    assert!(
        converged,
        "control plane did not re-converge to the shifted distribution (trips={}, recals={})",
        s.drift_trips,
        s.recalibrations
    );
    assert!(s.drift_trips >= 1, "recalibration without a drift trip");
    assert!(s.recalibrations >= 1);
    let new_profile = g.profile().expect("profile uninstalled by recalibration");
    assert!(!Arc::ptr_eq(&new_profile, &profile), "recalibration did not publish a new profile");

    // Quiet period: with the expectation inside the slack band, the
    // residual on further shifted traffic contributes nothing to the
    // CUSUM — the re-converged control plane must stop tripping.
    let trips_converged = g.status().drift_trips;
    for _ in 0..100 {
        submit_ok(&shifted);
    }
    assert_eq!(
        g.status().drift_trips,
        trips_converged,
        "re-converged profile kept tripping on its own distribution"
    );
    drop(g);
    coord.shutdown();
}

/// The ISSUE 6 chaos acceptance test: a fixed-seed fault plan injects
/// worker panics, corrupted frames, delays, and stalls while retrying
/// clients hammer the loopback server — every request must end with
/// complete, slot-ordered `Ok` results, and the supervisor must have
/// contained and respawned at least one panicked worker.
#[test]
fn chaos_soak_completes_every_request_and_respawns_workers() {
    // Rates raised well above the serving defaults so a short soak
    // deterministically exercises every injection site.
    let rates = FaultRates {
        panic_rate: 0.15,
        corrupt_rate: 0.03,
        delay_rate: 0.08,
        delay_max_ms: 3,
        stall_rate: 0.05,
        stall_max_ms: 5,
    };
    let fault = Arc::new(FaultPlan::with_rates(7, rates));
    // Observability on: every injection that fires must also land on
    // the flight recorder's "faults" ring, so the chaos run doubles as
    // the fault-event accounting test. A deep ring guarantees no drops
    // over the soak — the count comparison below is then exact.
    let obs = ObsConfig::enabled();
    let recorder = obs.recorder.clone().expect("enabled config carries a recorder");
    let fault_ring = recorder.ring_with_capacity("faults", 1 << 16);
    fault.attach_ring(Arc::clone(&fault_ring));
    let q = setup_q(83);
    let coord = Coordinator::start(
        BackendChoice::McuSim { q, mode: PruneMode::Unit, div: DivKind::Shift },
        ServeConfig { workers: 3, fault: Some(Arc::clone(&fault)), obs, ..Default::default() },
    );
    let metrics = Arc::clone(&coord.metrics);
    let server = Server::start(
        coord,
        "127.0.0.1:0",
        ServeOpts { max_conns: 8, fault: Some(Arc::clone(&fault)), ..Default::default() },
    )
    .expect("bind loopback");
    let addr = server.local_addr().to_string();

    let mnist = by_name("mnist", 17, Sizes { train: 2, val: 2, test: 6 });
    let n_samples = mnist.test.len();
    let xs: Vec<Vec<f32>> = (0..n_samples).map(|i| mnist.test.sample(i).to_vec()).collect();

    let n_clients = 3usize;
    let n_requests = 12usize;
    let ok_samples = Arc::new(AtomicU64::new(0));
    let handles: Vec<_> = (0..n_clients)
        .map(|c| {
            let addr = addr.clone();
            let xs = xs.clone();
            let ok_samples = Arc::clone(&ok_samples);
            std::thread::spawn(move || {
                let seed = 100 + c as u64;
                let cfg = RetryCfg { max_attempts: 64, seed, ..Default::default() };
                let client = RetryClient::connect(addr, cfg);
                for r in 0..n_requests {
                    let n = 1 + (r + c) % 3;
                    let batch: Vec<Vec<f32>> =
                        (0..n).map(|k| xs[(r + k) % xs.len()].clone()).collect();
                    // No deadline: under chaos the only legal terminal
                    // outcome is complete, ordered success.
                    let events = client
                        .infer_batch(&batch, None)
                        .expect("request lost under chaos (retry budget exhausted)");
                    assert_eq!(events.len(), n, "incomplete result under chaos");
                    for (slot, ev) in events.iter().enumerate() {
                        assert_eq!(ev.status, Status::Ok);
                        assert_eq!(ev.slot as usize, slot, "misordered result under chaos");
                    }
                    ok_samples.fetch_add(n as u64, Ordering::Relaxed);
                }
            })
        })
        .collect();
    for h in handles {
        h.join().expect("chaos client panicked");
    }

    // The soak above draws the panic site dozens of times at 15%, but
    // the draw sequence is a fixed function of the seed — top up with
    // singles until at least one panic provably happened, so the
    // respawn assertions cannot depend on seed luck.
    let cfg = RetryCfg { max_attempts: 64, seed: 999, ..Default::default() };
    let client = RetryClient::connect(addr, cfg);
    let mut topped_up = 0usize;
    while metrics.snapshot().worker_panics == 0 && topped_up < 400 {
        let ev = client.infer(&xs[topped_up % xs.len()], None).expect("top-up request lost");
        assert_eq!(ev.status, Status::Ok);
        topped_up += 1;
    }

    // Clean shutdown with the chaos plan still armed: drain, goodbye,
    // close — no hang, no thread panic propagating. Shutdown joins the
    // supervisor, so the final snapshot cannot catch a respawn counter
    // lagging its panic counter.
    drop(client);
    server.shutdown();

    let snap = metrics.snapshot();
    assert!(
        snap.worker_panics > 0,
        "chaos plan (seed 7) never injected a worker panic in {} draws",
        ok_samples.load(Ordering::Relaxed) as usize + topped_up
    );
    assert_eq!(snap.worker_panics, snap.respawns, "every contained panic must respawn its worker");
    assert!(snap.failed > 0, "panics terminalized no request as Failed");

    // Flight-recorder accounting: the "faults" ring must hold exactly
    // one Fault event per fired injection, per site — no drops, no
    // phantom events, sites attributed correctly.
    assert_eq!(fault_ring.dropped(), 0, "fault ring dropped events; deepen it");
    let mut per_site = [0u64; SITES];
    for e in fault_ring.snapshot() {
        assert_eq!(e.kind, EventKind::Fault, "non-fault event on the faults ring");
        per_site[e.a as usize] += 1;
    }
    for site in 0..SITES {
        assert_eq!(
            per_site[site],
            fault.injected(site),
            "site {site}: ring events vs fired injections"
        );
    }
    assert!(
        per_site[unit_pruner::util::fault::SITE_PANIC] > 0,
        "the soak provably panicked at least once, so the ring must show it"
    );
}
