//! Integration: fixed-point MCU engine vs float reference across all
//! Table-1 models, pruning modes and division estimators; plus
//! property-style sweeps of the skip-equivalence invariant.

use unit_pruner::approx::{DivApprox, DivExact, DivKind};
use unit_pruner::engine::{infer, EngineConfig, QModel};
use unit_pruner::models::{zoo, Params, MODEL_NAMES};
use unit_pruner::nn::{forward, ForwardOpts};
use unit_pruner::pruning::{apply_global_magnitude, Thresholds};
use unit_pruner::util::prop;

fn test_input(n: usize, salt: usize) -> Vec<f32> {
    (0..n).map(|i| (((i * 31 + salt * 7) % 37) as f32 - 18.0) / 12.0).collect()
}

#[test]
fn all_models_engine_matches_float_dense() {
    for name in MODEL_NAMES {
        let def = zoo(name);
        let params = Params::random(&def, 3);
        let q = QModel::quantize(&def, &params);
        let x = test_input(def.input_len(), 1);
        let (want, _) = forward(&def, &params, &x, &ForwardOpts::dense(def.layers.len()));
        let out = infer(&q, &q.quantize_input(&x), &EngineConfig::dense(&DivExact));
        // Rank agreement is what matters for accuracy parity: compare
        // argmax, and logits within quantization tolerance.
        let max_mag = want.iter().fold(0f32, |m, v| m.max(v.abs())).max(1.0);
        for (a, b) in out.logits.iter().zip(&want) {
            assert!(
                (a - b).abs() < 0.05 * max_mag + 0.5,
                "{name}: {a} vs {b} (max {max_mag})"
            );
        }
    }
}

#[test]
fn skip_fractions_track_float_across_thresholds() {
    for name in ["mnist", "widar"] {
        let def = zoo(name);
        let params = Params::random(&def, 5);
        let x = test_input(def.input_len(), 2);
        for t in [0.05f32, 0.2, 0.6] {
            let th = Thresholds::uniform(def.layers.len(), t);
            let q = QModel::quantize(&def, &params).with_thresholds(&th);
            let (_l, fs) = forward(&def, &params, &x, &ForwardOpts::unit(th.per_layer.clone()));
            let out = infer(&q, &q.quantize_input(&x), &EngineConfig::unit(&DivExact));
            let a = fs.skip_fraction();
            let b = out.skip_fraction();
            assert!((a - b).abs() < 0.1, "{name} t={t}: float {a:.3} vs fixed {b:.3}");
        }
    }
}

#[test]
fn every_division_estimator_preserves_mac_conservation() {
    let def = zoo("cifar");
    let params = Params::random(&def, 7);
    let th = Thresholds::uniform(def.layers.len(), 0.3);
    let q = QModel::quantize(&def, &params).with_thresholds(&th);
    let x = q.quantize_input(&test_input(def.input_len(), 3));
    let total = def.total_dense_macs();
    for kind in DivKind::all() {
        let d = kind.build();
        let cfg = EngineConfig::unit(d.as_ref());
        let out = infer(&q, &x, &cfg);
        assert_eq!(
            out.kept.iter().sum::<u64>() + out.skipped.iter().sum::<u64>(),
            total,
            "{}",
            d.name()
        );
        assert!(out.logits.iter().all(|v| v.is_finite()));
    }
}

#[test]
fn approx_divisions_cheaper_than_exact_at_engine_level() {
    let def = zoo("mnist");
    let params = Params::random(&def, 9);
    let th = Thresholds::uniform(3, 0.2);
    let q = QModel::quantize(&def, &params).with_thresholds(&th);
    let x = q.quantize_input(&test_input(def.input_len(), 4));
    let cycles = |kind: DivKind| {
        let d = kind.build();
        let cfg = EngineConfig::unit(d.as_ref());
        infer(&q, &x, &cfg).ledger.compute_cycles
    };
    let exact = cycles(DivKind::Exact);
    // Shift and tree return t>>⌊log2 c⌋ ≥ t/c: they only *over*-prune, so
    // they are strictly cheaper end-to-end. Mask reduces both operands to
    // exponents and can under-prune (keeping extra 77-cycle MACs), so for
    // it we only require the same order of magnitude — its win is the
    // constant 10-cycle division (asserted in the approx unit tests).
    for kind in [DivKind::Shift, DivKind::Tree] {
        assert!(cycles(kind) < exact, "{kind:?} not cheaper than exact division");
    }
    assert!(cycles(DivKind::Mask) < exact + exact / 3, "mask pathologically slow");
}

#[test]
fn ttp_static_sparse_full_cost_hierarchy() {
    // Paper ordering on a 50%-pruned model: static sparse deployment is
    // cheaper than dense; UnIT on top is cheaper still.
    let def = zoo("mnist");
    let params = Params::random(&def, 11);
    let ttp = apply_global_magnitude(&params, 0.5);
    let th = Thresholds::uniform(3, 0.2);
    let x_f = test_input(def.input_len(), 5);

    let q_dense = QModel::quantize(&def, &params);
    let q_ttp = QModel::quantize(&def, &ttp);
    let q_both = QModel::quantize(&def, &ttp).with_thresholds(&th);
    let x = q_dense.quantize_input(&x_f);

    let dense = infer(&q_dense, &x, &EngineConfig::dense(&DivExact));
    let ttp_run = infer(&q_ttp, &x, &EngineConfig::static_sparse(&DivExact));
    let both = infer(&q_both, &x, &EngineConfig::unit(&DivExact));

    assert!(ttp_run.ledger.total_cycles() < dense.ledger.total_cycles());
    assert!(both.ledger.total_cycles() < ttp_run.ledger.total_cycles());
    assert!(both.skip_fraction() > ttp_run.skip_fraction());
}

#[test]
fn prop_skip_equivalence_linear_eq2() {
    // Property (Eq. 2): with exact division, the MAC-free decision
    // |w_raw| > T_raw/|x_raw| must equal the product decision
    // |x_raw*w_raw| > T_raw up to integer-division rounding at the
    // boundary: specifically keep => product > T_raw strictly holds
    // one-sided; we assert decision agreement except when the product
    // lies within one |x| of the threshold (floor rounding band).
    prop::check(97, 5000, |g| {
        let xr = g.i32_in(-32768, 32767).max(1) as u32; // |x| >= 1
        let wr = g.i32_in(1, 127) as u32;
        let t_raw = g.u32_in(0, 1 << 22);
        let free = wr > DivExact.div(t_raw, xr); // engine decision
        let product = (wr as u64) * (xr as u64) > t_raw as u64; // Eq. 1 LHS
        if free != product {
            // disagreement only inside the rounding band
            let band = ((wr as u64) * (xr as u64)).abs_diff(t_raw as u64);
            assert!(band < xr as u64, "xr={xr} wr={wr} T={t_raw} band={band}");
        }
    });
}

#[test]
fn prop_fixed_engine_never_exceeds_float_magnitude_wildly() {
    // Fixed-point inference on bounded inputs must stay within the
    // representable Q8.8 envelope and track the float forward's argmax
    // most of the time on well-scaled models.
    prop::check(98, 10, |g| {
        let def = zoo("mnist");
        let params = Params::random(&def, g.case as u64 + 50);
        let q = QModel::quantize(&def, &params);
        let x: Vec<f32> = (0..def.input_len()).map(|_| g.f32_in(-1.5, 1.5)).collect();
        let (want, _) = forward(&def, &params, &x, &ForwardOpts::dense(3));
        let out = infer(&q, &q.quantize_input(&x), &EngineConfig::dense(&DivExact));
        let fa = unit_pruner::util::stats::argmax(&want);
        let qa = out.argmax();
        // allow argmax flips only when the float margin is tiny
        if fa != qa {
            let mut sorted = want.clone();
            sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
            assert!(sorted[0] - sorted[1] < 0.5, "argmax flip with large margin");
        }
    });
}

#[test]
fn prune_mode_cost_ordering_per_mode() {
    // Engine invariant: for the same model+input, per-connection cost
    // order is Unit(skip-heavy) < Dense, and ZeroSkip <= Dense on
    // sparse inputs.
    let def = zoo("mnist");
    let params = Params::random(&def, 13);
    let th = Thresholds::uniform(3, 0.4);
    let qd = QModel::quantize(&def, &params);
    let qu = qd.clone().with_thresholds(&th);
    let x_f: Vec<f32> = (0..def.input_len())
        .map(|i| if i % 4 == 0 { 0.0 } else { 0.8 })
        .collect();
    let x = qd.quantize_input(&x_f);
    let dense = infer(&qd, &x, &EngineConfig::dense(&DivExact));
    let zskip = infer(&qd, &x, &EngineConfig::zero_skip(&DivExact));
    let unit = infer(&qu, &x, &EngineConfig::unit(&DivExact));
    assert!(zskip.ledger.total_cycles() <= dense.ledger.total_cycles());
    assert!(unit.ledger.total_cycles() < dense.ledger.total_cycles());
}
