//! Integration: fixed-point MCU engine vs float reference across all
//! Table-1 models, pruning modes and division estimators; plus
//! property-style sweeps of the skip-equivalence invariant.

use unit_pruner::approx::{DivApprox, DivExact, DivKind};
use unit_pruner::engine::{
    infer, ConvInterior, EngineConfig, InferOutput, KernelBackend, PlanBacked, PlanConfig,
    PruneMode, QModel,
};
use unit_pruner::models::{zoo, ModelDef, Params, MODEL_NAMES};
use unit_pruner::nn::{forward, ForwardOpts, Layer};
use unit_pruner::pruning::{apply_global_magnitude, Thresholds};
use unit_pruner::util::prop;

fn test_input(n: usize, salt: usize) -> Vec<f32> {
    (0..n).map(|i| (((i * 31 + salt * 7) % 37) as f32 - 18.0) / 12.0).collect()
}

#[test]
fn all_models_engine_matches_float_dense() {
    for name in MODEL_NAMES {
        let def = zoo(name);
        let params = Params::random(&def, 3);
        let q = QModel::quantize(&def, &params);
        let x = test_input(def.input_len(), 1);
        let (want, _) = forward(&def, &params, &x, &ForwardOpts::dense(def.layers.len()));
        let out = infer(&q, &q.quantize_input(&x), &EngineConfig::dense(&DivExact));
        // Rank agreement is what matters for accuracy parity: compare
        // argmax, and logits within quantization tolerance.
        let max_mag = want.iter().fold(0f32, |m, v| m.max(v.abs())).max(1.0);
        for (a, b) in out.logits.iter().zip(&want) {
            assert!(
                (a - b).abs() < 0.05 * max_mag + 0.5,
                "{name}: {a} vs {b} (max {max_mag})"
            );
        }
    }
}

#[test]
fn skip_fractions_track_float_across_thresholds() {
    for name in ["mnist", "widar"] {
        let def = zoo(name);
        let params = Params::random(&def, 5);
        let x = test_input(def.input_len(), 2);
        for t in [0.05f32, 0.2, 0.6] {
            let th = Thresholds::uniform(def.layers.len(), t);
            let q = QModel::quantize(&def, &params).with_thresholds(&th);
            let (_l, fs) = forward(&def, &params, &x, &ForwardOpts::unit(th.per_layer.clone()));
            let out = infer(&q, &q.quantize_input(&x), &EngineConfig::unit(&DivExact));
            let a = fs.skip_fraction();
            let b = out.skip_fraction();
            assert!((a - b).abs() < 0.1, "{name} t={t}: float {a:.3} vs fixed {b:.3}");
        }
    }
}

#[test]
fn every_division_estimator_preserves_mac_conservation() {
    let def = zoo("cifar");
    let params = Params::random(&def, 7);
    let th = Thresholds::uniform(def.layers.len(), 0.3);
    let q = QModel::quantize(&def, &params).with_thresholds(&th);
    let x = q.quantize_input(&test_input(def.input_len(), 3));
    let total = def.total_dense_macs();
    for kind in DivKind::all() {
        let d = kind.build();
        let cfg = EngineConfig::unit(d.as_ref());
        let out = infer(&q, &x, &cfg);
        assert_eq!(
            out.kept.iter().sum::<u64>() + out.skipped.iter().sum::<u64>(),
            total,
            "{}",
            d.name()
        );
        assert!(out.logits.iter().all(|v| v.is_finite()));
    }
}

#[test]
fn approx_divisions_cheaper_than_exact_at_engine_level() {
    let def = zoo("mnist");
    let params = Params::random(&def, 9);
    let th = Thresholds::uniform(3, 0.2);
    let q = QModel::quantize(&def, &params).with_thresholds(&th);
    let x = q.quantize_input(&test_input(def.input_len(), 4));
    let cycles = |kind: DivKind| {
        let d = kind.build();
        let cfg = EngineConfig::unit(d.as_ref());
        infer(&q, &x, &cfg).ledger.compute_cycles
    };
    let exact = cycles(DivKind::Exact);
    // Shift and tree return t>>⌊log2 c⌋ ≥ t/c: they only *over*-prune, so
    // they are strictly cheaper end-to-end. Mask reduces both operands to
    // exponents and can under-prune (keeping extra 77-cycle MACs), so for
    // it we only require the same order of magnitude — its win is the
    // constant 10-cycle division (asserted in the approx unit tests).
    for kind in [DivKind::Shift, DivKind::Tree] {
        assert!(cycles(kind) < exact, "{kind:?} not cheaper than exact division");
    }
    assert!(cycles(DivKind::Mask) < exact + exact / 3, "mask pathologically slow");
}

#[test]
fn ttp_static_sparse_full_cost_hierarchy() {
    // Paper ordering on a 50%-pruned model: static sparse deployment is
    // cheaper than dense; UnIT on top is cheaper still.
    let def = zoo("mnist");
    let params = Params::random(&def, 11);
    let ttp = apply_global_magnitude(&params, 0.5);
    let th = Thresholds::uniform(3, 0.2);
    let x_f = test_input(def.input_len(), 5);

    let q_dense = QModel::quantize(&def, &params);
    let q_ttp = QModel::quantize(&def, &ttp);
    let q_both = QModel::quantize(&def, &ttp).with_thresholds(&th);
    let x = q_dense.quantize_input(&x_f);

    let dense = infer(&q_dense, &x, &EngineConfig::dense(&DivExact));
    let ttp_run = infer(&q_ttp, &x, &EngineConfig::static_sparse(&DivExact));
    let both = infer(&q_both, &x, &EngineConfig::unit(&DivExact));

    assert!(ttp_run.ledger.total_cycles() < dense.ledger.total_cycles());
    assert!(both.ledger.total_cycles() < ttp_run.ledger.total_cycles());
    assert!(both.skip_fraction() > ttp_run.skip_fraction());
}

#[test]
fn prop_skip_equivalence_linear_eq2() {
    // Property (Eq. 2): with exact division, the MAC-free decision
    // |w_raw| > T_raw/|x_raw| must equal the product decision
    // |x_raw*w_raw| > T_raw up to integer-division rounding at the
    // boundary: specifically keep => product > T_raw strictly holds
    // one-sided; we assert decision agreement except when the product
    // lies within one |x| of the threshold (floor rounding band).
    prop::check(97, 5000, |g| {
        let xr = g.i32_in(-32768, 32767).max(1) as u32; // |x| >= 1
        let wr = g.i32_in(1, 127) as u32;
        let t_raw = g.u32_in(0, 1 << 22);
        let free = wr > DivExact.div(t_raw, xr); // engine decision
        let product = (wr as u64) * (xr as u64) > t_raw as u64; // Eq. 1 LHS
        if free != product {
            // disagreement only inside the rounding band
            let band = ((wr as u64) * (xr as u64)).abs_diff(t_raw as u64);
            assert!(band < xr as u64, "xr={xr} wr={wr} T={t_raw} band={band}");
        }
    });
}

#[test]
fn prop_fixed_engine_never_exceeds_float_magnitude_wildly() {
    // Fixed-point inference on bounded inputs must stay within the
    // representable Q8.8 envelope and track the float forward's argmax
    // most of the time on well-scaled models.
    prop::check(98, 10, |g| {
        let def = zoo("mnist");
        let params = Params::random(&def, g.case as u64 + 50);
        let q = QModel::quantize(&def, &params);
        let x: Vec<f32> = (0..def.input_len()).map(|_| g.f32_in(-1.5, 1.5)).collect();
        let (want, _) = forward(&def, &params, &x, &ForwardOpts::dense(3));
        let out = infer(&q, &q.quantize_input(&x), &EngineConfig::dense(&DivExact));
        let fa = unit_pruner::util::stats::argmax(&want);
        let qa = out.argmax();
        // allow argmax flips only when the float margin is tiny
        if fa != qa {
            let mut sorted = want.clone();
            sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
            assert!(sorted[0] - sorted[1] < 0.5, "argmax flip with large margin");
        }
    });
}

// ---------------------------------------------------------------------
// Planned-engine equivalence: the prepacked execution plans
// (engine::plan) must be indistinguishable from the reference loops —
// bit-identical logits, per-layer kept/skipped counts, and the full
// ledger — for every model, mode, estimator, and threshold setting.
// ---------------------------------------------------------------------

const ALL_MODES: [PruneMode; 4] = [
    PruneMode::Dense,
    PruneMode::StaticSparse,
    PruneMode::ZeroSkip,
    PruneMode::Unit,
];

fn assert_equivalent(naive: &InferOutput, planned: &InferOutput, ctx: &str) {
    assert_eq!(planned.logits_raw, naive.logits_raw, "{ctx}: logits");
    assert_eq!(planned.kept, naive.kept, "{ctx}: kept");
    assert_eq!(planned.skipped, naive.skipped, "{ctx}: skipped");
    assert_eq!(planned.ledger.counts, naive.ledger.counts, "{ctx}: op counts");
    assert_eq!(
        planned.ledger.compute_cycles, naive.ledger.compute_cycles,
        "{ctx}: compute cycles"
    );
    assert_eq!(planned.ledger.mem_cycles, naive.ledger.mem_cycles, "{ctx}: mem cycles");
}

fn run_both(q: &QModel, x: &[i16], pcfg: PlanConfig) -> (InferOutput, InferOutput) {
    let d = pcfg.div.build();
    let cfg = EngineConfig {
        mode: pcfg.mode,
        div: d.as_ref(),
        sonic_accumulators: pcfg.sonic_accumulators,
        precomputed_conv_thresholds: pcfg.precomputed_conv_thresholds,
        t_scale_q8: pcfg.t_scale_q8,
    };
    let naive = infer(q, x, &cfg);
    let mut pb = PlanBacked::new(q, pcfg);
    let planned = pb.infer(x);
    (naive, planned)
}

#[test]
fn planned_equivalence_all_zoo_models_all_modes() {
    for name in MODEL_NAMES {
        let def = zoo(name);
        let params = Params::random(&def, 41);
        let th = Thresholds::uniform(def.layers.len(), 0.25);
        let x_f = test_input(def.input_len(), 6);
        for mode in ALL_MODES {
            let mut q = QModel::quantize(&def, &params);
            if mode == PruneMode::Unit {
                q = q.with_thresholds(&th);
            }
            let x = q.quantize_input(&x_f);
            let pcfg = PlanConfig::for_mode(mode, DivKind::Shift);
            let (naive, planned) = run_both(&q, &x, pcfg);
            assert_equivalent(&naive, &planned, &format!("{name}/{mode:?}"));
        }
    }
}

#[test]
fn planned_equivalence_all_division_estimators() {
    let def = zoo("cifar");
    let params = Params::random(&def, 43);
    let th = Thresholds::uniform(def.layers.len(), 0.3);
    let q = QModel::quantize(&def, &params).with_thresholds(&th);
    let x = q.quantize_input(&test_input(def.input_len(), 7));
    for kind in DivKind::all() {
        let pcfg = PlanConfig::unit(kind);
        let (naive, planned) = run_both(&q, &x, pcfg);
        assert_equivalent(&naive, &planned, &format!("cifar/unit/{kind:?}"));
    }
}

#[test]
fn planned_equivalence_on_ttp_sparse_weights() {
    // Statically sparse weights exercise the zero-weight plan pruning
    // in every mode (free skips, prefix nnz rows).
    let def = zoo("mnist");
    let params = apply_global_magnitude(&Params::random(&def, 47), 0.6);
    let th = Thresholds::uniform(3, 0.2);
    let x_f = test_input(def.input_len(), 8);
    for mode in ALL_MODES {
        let mut q = QModel::quantize(&def, &params);
        if mode == PruneMode::Unit {
            q = q.with_thresholds(&th);
        }
        let x = q.quantize_input(&x_f);
        let (naive, planned) = run_both(&q, &x, PlanConfig::for_mode(mode, DivKind::Mask));
        assert_equivalent(&naive, &planned, &format!("ttp/{mode:?}"));
    }
}

#[test]
fn prop_planned_equivalence_random_configs() {
    // Random model / thresholds (incl. per-channel groups) / FATReLU /
    // estimator / runtime scale / sonic / precomputed flags / sparse
    // inputs: the planned path may never drift from the reference.
    prop::check(4242, 30, |g| {
        let name = *g.choice(&["mnist", "cifar"]);
        let def = zoo(name);
        let params = Params::random(&def, g.case as u64 + 211);
        let nl = def.layers.len();
        let mut th = Thresholds::uniform(nl, 0.0);
        for t in th.per_layer.iter_mut() {
            *t = g.f32_in(0.0, 0.7);
        }
        if g.bool() {
            // per-output-channel refinement on the first conv layer
            let out_ch = 6; // both mnist/cifar conv1 have 6 output channels
            th.groups[0] = (0..out_ch).map(|_| g.f32_in(0.0, 0.6)).collect();
        }
        let mode = *g.choice(&ALL_MODES);
        let kind = *g.choice(&DivKind::all());
        let mut q = QModel::quantize(&def, &params);
        if mode == PruneMode::Unit {
            q = q.with_thresholds(&th);
        }
        if g.bool() {
            q = q.with_fatrelu(g.f32_in(0.0, 0.5));
        }
        let pcfg = PlanConfig {
            mode,
            div: kind,
            sonic_accumulators: g.bool(),
            precomputed_conv_thresholds: g.bool(),
            t_scale_q8: g.u32_in(0, 640),
            // Lane-packed and scalar interior kernels must both match
            // the naive engine bit for bit.
            conv_interior: *g.choice(&[ConvInterior::Lanes, ConvInterior::Scalar]),
            // Every kernel backend — including the intrinsic SIMD tile
            // path and the register-blocked linear rows it enables —
            // must also be bit-identical to the reference loops.
            kernel: *g.choice(&[
                KernelBackend::Auto,
                KernelBackend::Scalar,
                KernelBackend::Lanes,
                KernelBackend::Simd,
            ]),
        };
        let x_f = g.vec_sparse_normal(def.input_len(), 0.3);
        let x = q.quantize_input(&x_f);
        let (naive, planned) = run_both(&q, &x, pcfg);
        assert_equivalent(&naive, &planned, &format!("{name}/{mode:?}/{kind:?}/prop"));
    });
}

#[test]
fn planned_equivalence_border_only_conv_all_backends_all_divs() {
    // Degenerate conv shape: the kernel covers the whole input plane
    // (kh == h, kw == w), so the plan has zero interior pixels and the
    // entire layer runs through the border path. The kernel backend
    // must be irrelevant here — every backend × every division
    // estimator must stay bit-identical to the naive reference, and to
    // each other (the scalar plan is the cross-backend anchor).
    let def = ModelDef {
        name: "border-only".into(),
        input_shape: [2, 5, 5],
        classes: 3,
        layers: vec![
            Layer::Conv { out_ch: 4, in_ch: 2, kh: 5, kw: 5, pool: false },
            Layer::Linear { n_in: 4, n_out: 3, relu: false },
        ],
    };
    let params = Params::random(&def, 61);
    let th = Thresholds::uniform(def.layers.len(), 0.25);
    let x_f = test_input(def.input_len(), 9);
    for mode in ALL_MODES {
        let mut q = QModel::quantize(&def, &params);
        if mode == PruneMode::Unit {
            q = q.with_thresholds(&th);
        }
        let x = q.quantize_input(&x_f);
        for kind in DivKind::all() {
            let anchor = {
                let pcfg = PlanConfig {
                    kernel: KernelBackend::Scalar,
                    ..PlanConfig::for_mode(mode, kind)
                };
                let (naive, planned) = run_both(&q, &x, pcfg);
                assert_equivalent(
                    &naive,
                    &planned,
                    &format!("border/{mode:?}/{kind:?}/scalar"),
                );
                planned
            };
            for kernel in [KernelBackend::Auto, KernelBackend::Lanes, KernelBackend::Simd] {
                let pcfg = PlanConfig { kernel, ..PlanConfig::for_mode(mode, kind) };
                let mut pb = PlanBacked::new(&q, pcfg);
                let out = pb.infer(&x);
                assert_equivalent(
                    &anchor,
                    &out,
                    &format!("border/{mode:?}/{kind:?}/{}", kernel.name()),
                );
            }
        }
    }
}

#[test]
fn planned_serves_many_inferences_without_drift() {
    // Scratch reuse across a stream of different inputs (the serving
    // pattern) must match per-call naive inference every time.
    let def = zoo("mnist");
    let params = Params::random(&def, 53);
    let th = Thresholds::uniform(3, 0.25);
    let q = QModel::quantize(&def, &params).with_thresholds(&th);
    let d = DivKind::Shift.build();
    let cfg = EngineConfig::unit(d.as_ref());
    let mut pb = PlanBacked::new(&q, PlanConfig::unit(DivKind::Shift));
    for salt in 0..12 {
        let x = q.quantize_input(&test_input(def.input_len(), 100 + salt));
        let naive = infer(&q, &x, &cfg);
        let planned = pb.infer(&x);
        assert_equivalent(&naive, &planned, &format!("stream sample {salt}"));
    }
}

#[test]
fn prop_observed_inference_is_bit_identical_and_sink_totals_match() {
    // The observability hooks may never perturb the engine: for random
    // configs, `infer_observed(.., None)` and `infer_observed(.., sink)`
    // must both produce outputs bit-identical to `infer`, and the
    // per-layer (kept, skipped) pairs reported to the sink must equal
    // the InferOutput's own per-layer counts, layer for layer.
    use std::sync::Mutex;
    use unit_pruner::engine::PlannedModel;
    use unit_pruner::obs::LayerSink;

    struct CountingSink {
        rows: Mutex<Vec<(usize, u64, u64)>>,
    }
    impl LayerSink for CountingSink {
        fn layer(&self, index: usize, _elapsed_ns: u64, kept: u64, skipped: u64) {
            self.rows.lock().unwrap().push((index, kept, skipped));
        }
    }

    prop::check(5151, 20, |g| {
        let name = *g.choice(&["mnist", "cifar"]);
        let def = zoo(name);
        let params = Params::random(&def, g.case as u64 + 977);
        let mode = *g.choice(&ALL_MODES);
        let kind = *g.choice(&DivKind::all());
        let mut q = QModel::quantize(&def, &params);
        if mode == PruneMode::Unit {
            q = q.with_thresholds(&Thresholds::uniform(def.layers.len(), g.f32_in(0.0, 0.6)));
        }
        let x = q.quantize_input(&g.vec_sparse_normal(def.input_len(), 0.3));
        let plan = PlannedModel::compile(&q, PlanConfig::for_mode(mode, kind));
        let mut s = plan.new_scratch();
        let base = plan.infer(&x, &mut s);
        let unobserved = plan.infer_observed(&x, &mut s, None);
        let sink = CountingSink { rows: Mutex::new(Vec::new()) };
        let observed = plan.infer_observed(&x, &mut s, Some(&sink));
        for (out, ctx) in [(&unobserved, "sink=None"), (&observed, "sink=Some")] {
            assert_equivalent(&base, out, &format!("{name}/{mode:?}/{kind:?}/{ctx}"));
        }
        let rows = sink.rows.into_inner().unwrap();
        assert_eq!(rows.len(), base.kept.len(), "one sink report per layer");
        for (i, &(idx, kept, skipped)) in rows.iter().enumerate() {
            assert_eq!(idx, i, "sink reports must arrive in layer order");
            assert_eq!(kept, base.kept[i], "layer {i} kept");
            assert_eq!(skipped, base.skipped[i], "layer {i} skipped");
        }
    });
}

#[test]
fn prune_mode_cost_ordering_per_mode() {
    // Engine invariant: for the same model+input, per-connection cost
    // order is Unit(skip-heavy) < Dense, and ZeroSkip <= Dense on
    // sparse inputs.
    let def = zoo("mnist");
    let params = Params::random(&def, 13);
    let th = Thresholds::uniform(3, 0.4);
    let qd = QModel::quantize(&def, &params);
    let qu = qd.clone().with_thresholds(&th);
    let x_f: Vec<f32> = (0..def.input_len())
        .map(|i| if i % 4 == 0 { 0.0 } else { 0.8 })
        .collect();
    let x = qd.quantize_input(&x_f);
    let dense = infer(&qd, &x, &EngineConfig::dense(&DivExact));
    let zskip = infer(&qd, &x, &EngineConfig::zero_skip(&DivExact));
    let unit = infer(&qu, &x, &EngineConfig::unit(&DivExact));
    assert!(zskip.ledger.total_cycles() <= dense.ledger.total_cycles());
    assert!(unit.ledger.total_cycles() < dense.ledger.total_cycles());
}
