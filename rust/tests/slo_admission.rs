//! Per-tenant SLO admission acceptance (PR 9's tentpole).
//!
//! Two tenants behind one fleet server with a live SLO engine: the hot
//! tenant is driven against an impossible p99 latency objective until
//! its multi-window burn rate latches the trip. From then on its
//! admission is throttled — burst traffic sees `Throttled` refusals
//! and the fleet scheduler pins its allocation — while the healthy
//! tenant stays lossless and slot-ordered. Resetting the objective
//! over the wire (`SetSlo`) clears the trip and re-admits.

use std::sync::Arc;
use std::time::Duration;

use unit_pruner::approx::DivKind;
use unit_pruner::control::{calibrated_cache, FleetScheduler, ScaleGrid};
use unit_pruner::coordinator::{Coordinator, ModelSpec, ServeConfig};
use unit_pruner::data::{by_name, Sizes};
use unit_pruner::engine::{PlanConfig, PruneMode, QModel};
use unit_pruner::models::{zoo, Params};
use unit_pruner::obs::{AdmissionPolicy, SloEngine, SloWindows};
use unit_pruner::pruning::Thresholds;
use unit_pruner::serve::{Client, ServeOpts, Server, Status};

const SIZES: Sizes = Sizes { train: 2, val: 4, test: 8 };

fn model_q(name: &str, seed: u64) -> QModel {
    let def = zoo(name);
    let params = Params::random(&def, seed);
    QModel::quantize(&def, &params)
        .with_thresholds(&Thresholds::uniform(def.layers.len(), 0.2))
}

fn samples(name: &str, seed: u64) -> Vec<Vec<f32>> {
    let ds = by_name(name, seed, SIZES);
    (0..ds.test.len()).map(|i| ds.test.sample(i).to_vec()).collect()
}

fn poll_until(mut f: impl FnMut() -> bool, secs: u64) -> bool {
    let deadline = std::time::Instant::now() + Duration::from_secs(secs);
    while std::time::Instant::now() < deadline {
        if f() {
            return true;
        }
        std::thread::sleep(Duration::from_millis(20));
    }
    f()
}

/// A two-tenant fleet server with a generous budget, a scheduler, and
/// an SLO engine on fast test windows wired trip→scheduler.
fn fleet_with_slo(models: &[(&str, u64)]) -> (Server, Arc<FleetScheduler>, Arc<SloEngine>) {
    let specs: Vec<ModelSpec> = models
        .iter()
        .map(|&(name, seed)| ModelSpec {
            name: name.to_string(),
            q: model_q(name, seed),
            mode: PruneMode::Unit,
            div: DivKind::Exact,
        })
        .collect();
    let mut tenants = Vec::new();
    for (spec, &(name, seed)) in specs.iter().zip(models) {
        let ds = by_name(name, seed, SIZES);
        let cal: Vec<Vec<f32>> =
            (0..ds.val.len()).map(|i| ds.val.sample(i).to_vec()).collect();
        let (cache, profile) = calibrated_cache(
            spec.q.clone(),
            PlanConfig::for_mode(PruneMode::Unit, DivKind::Exact),
            ScaleGrid::default_grid(),
            &cal,
        );
        tenants.push((cache, profile));
    }
    let coord =
        Coordinator::start_multi(specs, ServeConfig { workers: 2, ..Default::default() });
    let sched = FleetScheduler::install(&coord, tenants, 1e12).expect("install");
    // Sub-second windows so the trip latches (and clears) within test
    // deadlines; trip/clear thresholds keep the SRE-workbook defaults.
    let windows = SloWindows {
        fast: Duration::from_millis(300),
        slow: Duration::from_millis(900),
        tick: Duration::from_millis(30),
        ..SloWindows::default()
    };
    let slo = SloEngine::new(
        models.iter().map(|&(n, _)| n.to_string()).collect(),
        Arc::clone(&coord.metrics),
        windows,
        AdmissionPolicy::default(),
    );
    {
        let sched2 = Arc::clone(&sched);
        slo.set_on_trip(move |model, tripped| {
            let _ = sched2.set_tenant_throttled(model, tripped);
        });
    }
    slo.start_ticker();
    let server = Server::start(
        coord,
        "127.0.0.1:0",
        ServeOpts {
            scheduler: Some(Arc::clone(&sched)),
            slo: Some(Arc::clone(&slo)),
            ..Default::default()
        },
    )
    .expect("bind loopback");
    (server, sched, slo)
}

#[test]
fn burn_trip_throttles_hot_tenant_and_spares_healthy_one() {
    let models: &[(&str, u64)] = &[("mnist", 81), ("cifar", 82)];
    let (server, sched, slo) = fleet_with_slo(models);
    let client = Client::connect(server.local_addr()).unwrap();
    let xs0 = samples("mnist", 81);
    let xs1 = samples("cifar", 82);

    // Declare an impossible latency objective for tenant 0 over the
    // wire: 0.001 ms, so every completed request violates and the burn
    // rate is 100x the violation budget on both windows.
    client.set_slo(0, 1e-3, 0.0, 0.0, Duration::from_secs(10)).unwrap();

    // Drive tenant 0 until the trip latches.
    let tripped = poll_until(
        || {
            let (_id, rx) = client.submit_to(0, &xs0[0], None).unwrap();
            let _ = rx.recv_timeout(Duration::from_secs(30)).unwrap();
            slo.tripped(0)
        },
        30,
    );
    assert!(tripped, "impossible objective never latched the burn trip");
    assert!(slo.status()[0].trips >= 1, "trip transition must be counted");
    assert!(sched.tenant_throttled(0), "trip must reach the scheduler");

    // A burst to the tripped tenant is refused with Throttled (token
    // bucket: 8 burst + 8/s refill; inflight quota 2) — never with an
    // error, and the session survives.
    let rxs: Vec<_> =
        (0..20).map(|_| client.submit_to(0, &xs0[0], None).unwrap().1).collect();
    let statuses: Vec<Status> = rxs
        .into_iter()
        .map(|rx| rx.recv_timeout(Duration::from_secs(60)).unwrap().status)
        .collect();
    let throttled = statuses.iter().filter(|s| **s == Status::Throttled).count();
    assert!(throttled > 0, "tripped tenant burst saw no Throttled refusals: {statuses:?}");
    assert!(
        statuses.iter().all(|s| matches!(s, Status::Ok | Status::Throttled)),
        "tripped tenant must only see Ok or Throttled: {statuses:?}"
    );

    // The healthy tenant is untouched: lossless, slot-ordered, and
    // never throttled.
    let (_id, rx) = client.submit_batch_to(1, &xs1, None).unwrap();
    for slot in 0..xs1.len() {
        let ev = rx.recv_timeout(Duration::from_secs(60)).unwrap();
        assert_eq!(ev.status, Status::Ok, "healthy tenant impacted by neighbor's trip");
        assert_eq!(ev.slot as usize, slot, "healthy tenant sub-replies out of order");
    }
    let snap = server.metrics().tenant_snapshot();
    assert_eq!(snap.get(1).map_or(0, |t| t.throttled), 0, "healthy tenant was throttled");
    assert!(
        snap.first().map_or(0, |t| t.throttled) as usize >= throttled,
        "throttled refusals must land on the hot tenant's counter"
    );

    // Resetting the objective over the wire clears the trip, unpins
    // the scheduler, and re-admits.
    client.set_slo(0, 0.0, 0.0, 0.0, Duration::from_secs(10)).unwrap();
    assert!(
        poll_until(|| !slo.tripped(0) && !sched.tenant_throttled(0), 10),
        "objective reset must clear the trip and the scheduler pin"
    );
    let (_id, rx) = client.submit_to(0, &xs0[0], None).unwrap();
    assert_eq!(
        rx.recv_timeout(Duration::from_secs(60)).unwrap().status,
        Status::Ok,
        "recovered tenant must be re-admitted"
    );
    assert!(client.goodbye(Duration::from_secs(10)));
    server.shutdown();
}
