//! The streamed-serving acceptance tests.
//!
//! Part 1 — wire codec properties (pure, in-memory): random frames
//! round-trip bit-exactly; truncation is "need more bytes", never an
//! error; corruption is an error, never a panic; arbitrary garbage
//! never panics the decoder.
//!
//! Part 2 — loopback e2e over a real `Server`:
//!
//! * a client that overruns its in-flight window gets
//!   backpressure-rejected frames while every admitted request still
//!   completes, bit-identical to the in-process engine;
//! * an expired-deadline request returns `Expired` without occupying a
//!   shard (the worker drops the tombstone; `dropped` metric proves
//!   it);
//! * cancelling a split batch mid-stream suppresses every remaining
//!   sub-reply — delivered slots are a contiguous ordered prefix and
//!   the wire stays silent for that id afterwards;
//! * listener shutdown with open sessions drains without panicking
//!   (close listener → drain sessions → close pool), and a session
//!   racing the closed pool answers `Error` instead of crashing.

use std::time::Duration;

use unit_pruner::coordinator::{BackendChoice, Coordinator, Placement, ServeConfig};
use unit_pruner::data::{mnist_like, Sizes};
use unit_pruner::engine::{PlanBacked, PlanConfig, PruneMode, QModel};
use unit_pruner::models::{zoo, Params};
use unit_pruner::pruning::Thresholds;
use unit_pruner::serve::{
    wire, Client, Frame, FrameReader, Payload, ServeOpts, Server, SessionCfg, Status,
    WHOLE_REQUEST,
};
use unit_pruner::util::prop::{check, Gen};

// ---------------------------------------------------------------------------
// Part 1: codec properties

fn arbitrary_frame(g: &mut Gen) -> Frame {
    match g.usize_in(0, 10) {
        0 => {
            let sample_len = g.usize_in(1, 32);
            let n_samples = g.usize_in(1, 5);
            let n = sample_len * n_samples;
            let data = if g.bool() {
                Payload::F32((0..n).map(|_| g.f32_in(-4.0, 4.0)).collect())
            } else {
                Payload::I8((0..n).map(|_| g.i32_in(-128, 127) as i8).collect())
            };
            Frame::Request {
                id: g.u32_in(0, u32::MAX - 1) as u64,
                deadline_ms: g.u32_in(0, 100_000),
                sample_len: sample_len as u32,
                model: g.u32_in(0, 8),
                data,
            }
        }
        1 => Frame::Response {
            id: g.u32_in(0, u32::MAX - 1) as u64,
            slot: if g.bool() { g.u32_in(0, 1000) } else { WHOLE_REQUEST },
            status: *g.choice(&[
                Status::Ok,
                Status::Rejected,
                Status::Expired,
                Status::Cancelled,
                Status::Error,
                Status::Throttled,
            ]),
            predicted: g.u32_in(0, u16::MAX as u32) as u16,
            queue_us: g.u32_in(0, u32::MAX - 1),
            service_us: g.u32_in(0, u32::MAX - 1),
            mac_skipped: g.f32_in(0.0, 1.0),
            logits: (0..g.usize_in(0, 40)).map(|_| g.normal()).collect(),
        },
        2 => Frame::Cancel { id: g.u32_in(0, u32::MAX - 1) as u64 },
        3 => Frame::Ping { id: g.u32_in(0, u32::MAX - 1) as u64 },
        4 => Frame::Pong { id: g.u32_in(0, u32::MAX - 1) as u64 },
        5 => Frame::SetBudget {
            id: g.u32_in(0, u32::MAX - 1) as u64,
            // Finite values only: NaN would break the equality check,
            // and the protocol treats <= 0.0 as a pure query anyway.
            budget_mj: g.f32_in(0.0, 1000.0) as f64,
            model: if g.bool() { wire::FLEET_MODEL } else { g.u32_in(0, 8) },
        },
        6 => Frame::Stats {
            id: g.u32_in(0, u32::MAX - 1) as u64,
            scale_q8: g.u32_in(0, 4096),
            step: g.u32_in(0, 64),
            steps_total: g.u32_in(0, 64),
            budget_mj: g.f32_in(0.0, 1000.0) as f64,
            ewma_mj: g.f32_in(0.0, 1000.0) as f64,
            keep_ratio: g.f32_in(0.0, 1.0),
            cache_hits: g.u32_in(0, u32::MAX - 1) as u64,
            cache_misses: g.u32_in(0, u32::MAX - 1) as u64,
            swaps: g.u32_in(0, u32::MAX - 1) as u64,
            bg_pending: g.u32_in(0, 64) as u64,
            bg_compiled: g.u32_in(0, u32::MAX - 1) as u64,
            bg_upgrades: g.u32_in(0, u32::MAX - 1) as u64,
            worker_panics: g.u32_in(0, u32::MAX - 1) as u64,
            respawns: g.u32_in(0, u32::MAX - 1) as u64,
            drift_trips: g.u32_in(0, u32::MAX - 1) as u64,
            recalibrations: g.u32_in(0, u32::MAX - 1) as u64,
            model: g.u32_in(0, 8),
            models_loaded: g.u32_in(0, 8),
            fleet_budget_mj: g.f32_in(0.0, 1000.0) as f64,
        },
        7 => {
            // Printable ASCII bodies: Prometheus text / JSON are what
            // ride these frames in practice, and UTF-8 validity is a
            // decode invariant.
            let body: String =
                (0..g.usize_in(0, 64)).map(|_| g.u32_in(0x20, 0x7E) as u8 as char).collect();
            Frame::Scrape { id: g.u32_in(0, u32::MAX - 1) as u64, body }
        }
        8 => {
            let body: String =
                (0..g.usize_in(0, 64)).map(|_| g.u32_in(0x20, 0x7E) as u8 as char).collect();
            Frame::TraceDump { id: g.u32_in(0, u32::MAX - 1) as u64, body }
        }
        9 => Frame::SetSlo {
            id: g.u32_in(0, u32::MAX - 1) as u64,
            model: g.u32_in(0, 8),
            // Finite values only (same reasoning as SetBudget above);
            // <= 0 components mean "objective disabled".
            p99_ms: g.f32_in(0.0, 10_000.0) as f64,
            keep_floor: g.f32_in(0.0, 1.0),
            err_ceiling: g.f32_in(0.0, 1.0),
        },
        _ => Frame::Goodbye,
    }
}

#[test]
fn random_frames_roundtrip_exactly() {
    check(0x31BE, 400, |g| {
        let frame = arbitrary_frame(g);
        let bytes = wire::encode(&frame);
        let (decoded, consumed) = wire::decode(&bytes).unwrap().expect("complete frame");
        assert_eq!(consumed, bytes.len());
        assert_eq!(decoded, frame);
    });
}

#[test]
fn truncation_is_incomplete_never_error() {
    check(0x7123, 150, |g| {
        let frame = arbitrary_frame(g);
        let bytes = wire::encode(&frame);
        let cut = g.usize_in(0, bytes.len() - 1);
        assert_eq!(wire::decode(&bytes[..cut]).unwrap(), None, "cut at {cut}");
    });
}

#[test]
fn corruption_is_error_never_panic_or_silent_accept() {
    check(0xC0DE, 300, |g| {
        let frame = arbitrary_frame(g);
        let mut bytes = wire::encode(&frame);
        // Corrupt one byte past the length prefix: CRC (or a stricter
        // structural check) must catch every single-byte flip.
        let i = g.usize_in(4, bytes.len() - 1);
        let flip = g.u32_in(1, 255) as u8;
        bytes[i] ^= flip;
        assert!(
            wire::decode(&bytes).is_err(),
            "flip {flip:#x} at byte {i} decoded silently"
        );
    });
}

#[test]
fn garbage_never_panics() {
    check(0x6A5B, 300, |g| {
        let n = g.usize_in(0, 256);
        let garbage: Vec<u8> = (0..n).map(|_| g.i32_in(0, 255) as u8).collect();
        // Any outcome but a panic is acceptable.
        let _ = wire::decode(&garbage);
        let mut r = FrameReader::new();
        r.feed(&garbage);
        let _ = r.next();
    });
}

#[test]
fn frame_streams_survive_random_chunking() {
    check(0x5EAD, 60, |g| {
        let frames: Vec<Frame> = (0..g.usize_in(1, 8)).map(|_| arbitrary_frame(g)).collect();
        let mut stream = Vec::new();
        for f in &frames {
            stream.extend(wire::encode(f));
        }
        let mut r = FrameReader::new();
        let mut got = Vec::new();
        let mut off = 0usize;
        while off < stream.len() {
            let n = g.usize_in(1, 97).min(stream.len() - off);
            r.feed(&stream[off..off + n]);
            off += n;
            while let Some(f) = r.next().unwrap() {
                got.push(f);
            }
        }
        assert_eq!(got, frames);
        assert_eq!(r.pending(), 0);
    });
}

// ---------------------------------------------------------------------------
// Part 2: loopback e2e

fn setup_q(seed: u64) -> QModel {
    let def = zoo("mnist");
    let params = Params::random(&def, seed);
    QModel::quantize(&def, &params).with_thresholds(&Thresholds::uniform(3, 0.2))
}

fn start_server(q: QModel, workers: usize, session: SessionCfg) -> Server {
    let div = unit_pruner::approx::DivKind::Exact;
    let coord = Coordinator::start(
        BackendChoice::McuSim { q, mode: PruneMode::Unit, div },
        ServeConfig { workers, placement: Placement::CostWeighted, ..Default::default() },
    );
    let opts = ServeOpts { max_conns: 8, session, ..Default::default() };
    Server::start(coord, "127.0.0.1:0", opts).expect("bind loopback")
}

#[test]
fn loopback_results_bit_identical_to_in_process() {
    let q = setup_q(31);
    let ds = mnist_like::generate(12, Sizes { train: 2, val: 2, test: 12 });
    let server = start_server(q.clone(), 3, SessionCfg::default());
    let client = Client::connect(server.local_addr()).unwrap();

    // Direct plan-backed engine = what in-process submit_batch returns.
    let mut pb = PlanBacked::new(
        &q,
        PlanConfig::for_mode(PruneMode::Unit, unit_pruner::approx::DivKind::Exact),
    );
    let xs: Vec<Vec<f32>> = (0..ds.test.len()).map(|i| ds.test.sample(i).to_vec()).collect();
    let (_id, rx) = client.submit_batch(&xs, None).unwrap();
    for (slot, x) in xs.iter().enumerate() {
        let ev = rx.recv_timeout(Duration::from_secs(60)).unwrap();
        assert_eq!(ev.status, Status::Ok);
        assert_eq!(ev.slot as usize, slot, "sub-replies out of slot order");
        let direct = pb.infer(&pb.quantize_input(x));
        // f32 values cross the wire as exact LE bytes: bit-identical.
        assert_eq!(ev.logits, direct.logits, "slot {slot} logits differ from in-process");
        assert_eq!(ev.predicted as usize, direct.argmax());
        assert!((ev.mac_skipped as f64 - direct.skip_fraction()).abs() < 1e-6);
    }
    assert!(client.goodbye(Duration::from_secs(10)));
    let snap = server.metrics().snapshot();
    assert_eq!(snap.served, xs.len() as u64);
    assert_eq!(snap.rejected + snap.expired + snap.cancelled, 0);
    server.shutdown();
}

#[test]
fn i8_payload_served_as_dequantized_f32() {
    let q = setup_q(32);
    let server = start_server(q.clone(), 2, SessionCfg::default());
    let client = Client::connect(server.local_addr()).unwrap();
    let def = zoo("mnist");
    let flat: Vec<i8> = (0..def.input_len()).map(|i| ((i * 37) % 255) as i8).collect();
    let (_id, rx) = client.submit_i8(&flat, def.input_len(), None).unwrap();
    let ev = rx.recv_timeout(Duration::from_secs(60)).unwrap();
    assert_eq!(ev.status, Status::Ok);
    let mut pb = PlanBacked::new(
        &q,
        PlanConfig::for_mode(PruneMode::Unit, unit_pruner::approx::DivKind::Exact),
    );
    let x: Vec<f32> = flat.iter().map(|&b| b as f32 / 127.0).collect();
    let direct = pb.infer(&pb.quantize_input(&x));
    assert_eq!(ev.logits, direct.logits);
    drop(client);
    server.shutdown();
}

/// Acceptance: a slow client overrunning its window sees `Rejected`
/// frames; everything admitted still completes correctly.
#[test]
fn backpressure_rejects_past_the_inflight_window() {
    let q = setup_q(33);
    let ds = mnist_like::generate(13, Sizes { train: 2, val: 2, test: 8 });
    // window of 2 on one worker: deterministic pressure.
    let server = start_server(
        q,
        1,
        SessionCfg { max_inflight: 2, ..Default::default() },
    );
    let client = Client::connect(server.local_addr()).unwrap();
    // Two big batches occupy the window; they take a while on 1 worker.
    let big: Vec<Vec<f32>> =
        (0..64).map(|i| ds.test.sample(i % ds.test.len()).to_vec()).collect();
    let (_ia, rx_a) = client.submit_batch(&big, None).unwrap();
    let (_ib, rx_b) = client.submit_batch(&big, None).unwrap();
    // Overrun: burst more requests while the window is full. At least
    // the first of these must observe the full window (the admitted
    // pair cannot finish faster than loopback latency); any that land
    // after the window frees may legally succeed.
    let mut rejected = 0usize;
    let mut overrun_rxs = Vec::new();
    for i in 0..4 {
        let (_, rx) =
            client.submit(ds.test.sample(i % ds.test.len()), None).unwrap();
        overrun_rxs.push(rx);
    }
    for rx in &overrun_rxs {
        let ev = rx.recv_timeout(Duration::from_secs(60)).unwrap();
        match ev.status {
            Status::Rejected => {
                assert_eq!(ev.slot, WHOLE_REQUEST);
                rejected += 1;
            }
            Status::Ok => {}
            other => panic!("unexpected overrun status {other:?}"),
        }
    }
    assert!(rejected > 0, "window of 2 never rejected a 4-deep overrun burst");
    // The admitted batches still complete, in order.
    for rx in [rx_a, rx_b] {
        for slot in 0..big.len() {
            let ev = rx.recv_timeout(Duration::from_secs(120)).unwrap();
            assert_eq!(ev.status, Status::Ok);
            assert_eq!(ev.slot as usize, slot);
        }
    }
    let snap = server.metrics().snapshot();
    assert_eq!(snap.rejected, rejected as u64);
    assert!(client.goodbye(Duration::from_secs(10)));
    server.shutdown();
}

/// Acceptance: a request whose deadline passes while queued returns
/// `Expired` and never occupies a shard (workers drop the tombstone).
#[test]
fn expired_deadline_returns_expired_without_occupying_a_shard() {
    let q = setup_q(34);
    let ds = mnist_like::generate(14, Sizes { train: 2, val: 2, test: 8 });
    let server = start_server(q, 1, SessionCfg { max_inflight: 8, ..Default::default() });
    let client = Client::connect(server.local_addr()).unwrap();
    // Fill the single worker's queue with enough work that the 1 ms
    // deadline below cannot be beaten even on a fast machine…
    let big: Vec<Vec<f32>> =
        (0..192).map(|i| ds.test.sample(i % ds.test.len()).to_vec()).collect();
    let (_ib, rx_big) = client.submit_batch(&big, None).unwrap();
    // …then a 1 ms-deadline request stuck behind it.
    let (_ie, rx_exp) =
        client.submit(ds.test.sample(0), Some(Duration::from_millis(1))).unwrap();
    let ev = rx_exp.recv_timeout(Duration::from_secs(60)).unwrap();
    assert_eq!(ev.status, Status::Expired, "queued past its deadline");
    assert_eq!(ev.slot, WHOLE_REQUEST);
    // No further events for the expired id.
    assert!(rx_exp.recv_timeout(Duration::from_millis(300)).is_err());
    // The big batch is unaffected.
    for slot in 0..big.len() {
        let ev = rx_big.recv_timeout(Duration::from_secs(120)).unwrap();
        assert_eq!((ev.status, ev.slot as usize), (Status::Ok, slot));
    }
    // The tombstone was dropped at dequeue: the expired sample was
    // never served, and the worker recorded the drop. The pop of the
    // tombstone races this snapshot by microseconds, so poll briefly.
    let deadline = std::time::Instant::now() + Duration::from_secs(5);
    let snap = loop {
        let snap = server.metrics().snapshot();
        if snap.dropped >= 1 || std::time::Instant::now() > deadline {
            break snap;
        }
        std::thread::sleep(Duration::from_millis(10));
    };
    assert_eq!(snap.expired, 1);
    assert_eq!(snap.served, big.len() as u64);
    assert_eq!(snap.dropped, 1);
    assert!(client.goodbye(Duration::from_secs(10)));
    server.shutdown();
}

/// Acceptance: cancelling a split batch mid-stream suppresses every
/// remaining sub-reply; what was delivered is a contiguous ordered
/// prefix.
#[test]
fn mid_batch_cancel_suppresses_remaining_sub_replies() {
    let q = setup_q(35);
    let ds = mnist_like::generate(15, Sizes { train: 2, val: 2, test: 8 });
    let server = start_server(q, 1, SessionCfg { max_inflight: 8, ..Default::default() });
    let client = Client::connect(server.local_addr()).unwrap();
    let n = 96usize;
    let xs: Vec<Vec<f32>> =
        (0..n).map(|i| ds.test.sample(i % ds.test.len()).to_vec()).collect();
    let (id, rx) = client.submit_batch(&xs, None).unwrap();
    // Read a few sub-replies, then cancel mid-batch.
    let mut got = 0usize;
    for slot in 0..4 {
        let ev = rx.recv_timeout(Duration::from_secs(60)).unwrap();
        assert_eq!((ev.status, ev.slot as usize), (Status::Ok, slot));
        got += 1;
    }
    client.cancel(id).unwrap();
    // Client-side the receiver disconnects at cancel (the contract is
    // silence, so the pending entry retires immediately). Anything
    // that still drains out arrived before the cancel.
    while let Ok(ev) = rx.recv_timeout(Duration::from_millis(500)) {
        assert_eq!((ev.status, ev.slot as usize), (Status::Ok, got), "post-cancel reorder");
        got += 1;
        assert!(got < n, "cancellation suppressed nothing ({got}/{n} delivered)");
    }
    assert!(got < n, "cancellation suppressed nothing ({got}/{n} delivered)");
    // Server-side proof of suppression: the cancel was booked, the
    // queued tail was tombstone-dropped (never executed), and the
    // executed+dropped ledger accounts for every sample of the batch —
    // nothing was silently lost. Poll briefly: the workers race this
    // snapshot while draining the tombstones.
    // (The follow-up request below is not submitted yet, so every
    // sample counted here belongs to the cancelled batch.)
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    let snap = loop {
        let snap = server.metrics().snapshot();
        if snap.served + snap.dropped >= n as u64 || std::time::Instant::now() > deadline {
            break snap;
        }
        std::thread::sleep(Duration::from_millis(10));
    };
    assert_eq!(snap.cancelled, 1);
    assert!(
        snap.dropped > 0,
        "queued tail should be tombstone-dropped, not executed"
    );
    assert!(
        (snap.served as usize) < n,
        "cancellation executed the whole batch anyway"
    );
    assert_eq!(snap.served + snap.dropped, n as u64, "samples unaccounted for");
    // The session survives: a follow-up request on the same connection
    // completes normally.
    let (_i2, rx2) = client.submit(ds.test.sample(0), None).unwrap();
    assert_eq!(rx2.recv_timeout(Duration::from_secs(60)).unwrap().status, Status::Ok);
    assert!(client.goodbye(Duration::from_secs(10)));
    server.shutdown();
}

/// Regression (satellite): shutting the listener down with open
/// sessions and queued work drains cleanly — close listener → drain
/// sessions → close pool — without panicking, and every in-flight
/// sample is answered before the goodbye.
#[test]
fn shutdown_with_open_sessions_drains_without_panicking() {
    let q = setup_q(36);
    let ds = mnist_like::generate(16, Sizes { train: 2, val: 2, test: 8 });
    let server = start_server(q, 2, SessionCfg::default());
    let addr = server.local_addr();
    let clients: Vec<_> =
        (0..3)
            .map(|c| {
                let client = Client::connect(addr).unwrap();
                let n = 8 + 4 * c;
                let xs: Vec<Vec<f32>> =
                    (0..n).map(|i| ds.test.sample(i % ds.test.len()).to_vec()).collect();
                let (_id, rx) = client.submit_batch(&xs, None).unwrap();
                (client, rx, n)
            })
            .collect();
    // Shut down while all three sessions have work in flight.
    let t = std::thread::spawn(move || server.shutdown());
    for (client, rx, n) in clients {
        for slot in 0..n {
            let ev = rx.recv_timeout(Duration::from_secs(120)).unwrap();
            assert_eq!((ev.status, ev.slot as usize), (Status::Ok, slot));
        }
        // After the drain the server says goodbye and the socket
        // closes; the client observes it.
        let deadline = std::time::Instant::now() + Duration::from_secs(30);
        while !client.is_closed() && std::time::Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(10));
        }
        assert!(client.is_closed(), "no goodbye after drain");
    }
    t.join().expect("shutdown panicked");
}

/// A session that submits into an already-closed pool answers `Error`
/// instead of panicking (the old drop-order crash).
#[test]
fn submit_racing_pool_close_yields_error_not_panic() {
    let q = setup_q(37);
    let ds = mnist_like::generate(17, Sizes { train: 2, val: 2, test: 4 });
    let server = start_server(q, 2, SessionCfg::default());
    let client = Client::connect(server.local_addr()).unwrap();
    // Reach under the hood: close the coordinator's intake while the
    // listener and session still run (the pathological ordering the
    // old Coordinator::drop could produce).
    let metrics = server.metrics();
    server.coordinator().close();
    let (_id, rx) = client.submit(ds.test.sample(0), None).unwrap();
    let ev = rx.recv_timeout(Duration::from_secs(30)).unwrap();
    assert_eq!(ev.status, Status::Error, "closed pool must answer Error");
    assert_eq!(metrics.snapshot().served, 0);
    drop(client);
    server.shutdown();
}
