//! Integration: the serving coordinator end-to-end — no request lost,
//! FIFO batching, correct predictions vs direct engine calls, clean
//! shutdown under load, work stealing with mixed single/batched
//! submissions, and the PJRT backend (artifact-gated).

use std::time::Duration;

use unit_pruner::approx::{DivExact, DivKind};
use unit_pruner::coordinator::{BackendChoice, Coordinator, ServeConfig};
use unit_pruner::data::{mnist_like, Sizes};
use unit_pruner::engine::{infer, EngineConfig, PruneMode, QModel};
use unit_pruner::models::{zoo, Params};
use unit_pruner::pruning::Thresholds;
use unit_pruner::runtime::ArtifactStore;

fn setup() -> (QModel, unit_pruner::data::Dataset) {
    let def = zoo("mnist");
    let params = Params::random(&def, 21);
    let th = Thresholds::uniform(3, 0.2);
    let q = QModel::quantize(&def, &params).with_thresholds(&th);
    let ds = mnist_like::generate(9, Sizes { train: 4, val: 4, test: 24 });
    (q, ds)
}

#[test]
fn coordinator_matches_direct_engine_calls() {
    let (q, ds) = setup();
    let coord = Coordinator::start(
        BackendChoice::McuSim { q: q.clone(), mode: PruneMode::Unit, div: DivKind::Exact },
        ServeConfig { workers: 2, ..Default::default() },
    );
    let rxs: Vec<_> = (0..ds.test.len()).map(|i| coord.submit(ds.test.sample(i).to_vec())).collect();
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx.recv().unwrap();
        let direct = infer(&q, &q.quantize_input(ds.test.sample(i)), &EngineConfig::unit(&DivExact));
        assert_eq!(resp.predicted, direct.argmax(), "sample {i}");
        assert!((resp.mac_skipped - direct.skip_fraction()).abs() < 1e-12);
    }
    coord.shutdown();
}

#[test]
fn hundreds_of_requests_none_lost() {
    let (q, ds) = setup();
    let coord = Coordinator::start(
        BackendChoice::McuSim { q, mode: PruneMode::Unit, div: DivKind::Shift },
        ServeConfig { workers: 4, ..Default::default() },
    );
    let n = 300usize;
    let rxs: Vec<_> = (0..n).map(|i| coord.submit(ds.test.sample(i % ds.test.len()).to_vec())).collect();
    let mut ids = std::collections::HashSet::new();
    for rx in rxs {
        let resp = rx.recv_timeout(Duration::from_secs(60)).unwrap();
        assert!(ids.insert(resp.id), "duplicate response id {}", resp.id);
    }
    assert_eq!(ids.len(), n);
    let snap = coord.metrics.snapshot();
    assert_eq!(snap.served, n as u64);
    coord.shutdown();
}

/// The work-stealing acceptance test: a storm of interleaved single
/// and batched submissions across a sharded pool must lose nothing and
/// reorder nothing — every single reply carries its own request's id,
/// and every batch comes back in input order with per-slot results
/// identical to direct engine calls.
#[test]
fn work_stealing_mixed_singles_and_batches_nothing_lost_or_reordered() {
    let (q, ds) = setup();
    let coord = Coordinator::start(
        BackendChoice::McuSim { q: q.clone(), mode: PruneMode::Unit, div: DivKind::Exact },
        ServeConfig { workers: 4, ..Default::default() },
    );
    let sample = |i: usize| ds.test.sample(i % ds.test.len()).to_vec();
    // Interleave: (batch of 9) (3 singles) (batch of 17) (3 singles) ...
    let batch_sizes = [9usize, 17, 1, 30, 5];
    let mut single_rxs = Vec::new(); // (sample idx, rx)
    let mut batch_rxs = Vec::new(); // (start idx, size, rx)
    let mut next = 0usize;
    for (k, &bs) in batch_sizes.iter().enumerate() {
        let xs: Vec<Vec<f32>> = (0..bs).map(|j| sample(next + j)).collect();
        batch_rxs.push((next, bs, coord.submit_batch(xs)));
        next += bs;
        for _ in 0..3 {
            single_rxs.push((next + k, coord.submit(sample(next + k))));
            next += 1;
        }
    }
    let direct = |i: usize| {
        let xi = q.quantize_input(ds.test.sample(i % ds.test.len()));
        infer(&q, &xi, &EngineConfig::unit(&DivExact))
    };
    let mut seen_ids = std::collections::HashSet::new();
    let mut total = 0usize;
    for (start, size, rx) in batch_rxs {
        let out = rx.recv_timeout(Duration::from_secs(60)).unwrap();
        assert_eq!(out.len(), size, "batch at {start} wrong size");
        for (slot, resp) in out.iter().enumerate() {
            // in-order reassembly: ids were assigned consecutively at
            // submit, so slot order must equal id order...
            assert_eq!(resp.id - out[0].id, slot as u64, "batch at {start}: slot {slot}");
            // ...and each slot's result equals the direct engine call
            // for exactly that input.
            let d = direct(start + slot);
            assert_eq!(resp.predicted, d.argmax(), "batch at {start}: slot {slot}");
            assert_eq!(resp.logits, d.logits, "batch at {start}: slot {slot}");
            assert!(seen_ids.insert(resp.id));
            assert_eq!(resp.latency_us, resp.queue_us + resp.service_us);
        }
        total += size;
    }
    for (idx, rx) in single_rxs {
        let resp = rx.recv_timeout(Duration::from_secs(60)).unwrap();
        let d = direct(idx);
        assert_eq!(resp.predicted, d.argmax(), "single for sample {idx}");
        assert_eq!(resp.logits, d.logits, "single for sample {idx}");
        assert!(seen_ids.insert(resp.id));
        total += 1;
    }
    assert_eq!(seen_ids.len(), total, "a response was lost or duplicated");
    let snap = coord.metrics.snapshot();
    assert_eq!(snap.served, total as u64);
    // one metrics batch per submit_batch + one per single
    assert_eq!(snap.batches, (batch_sizes.len() + 3 * batch_sizes.len()) as u64);
    coord.shutdown();
}

#[test]
fn shutdown_with_empty_queue_is_clean() {
    let (q, _ds) = setup();
    let coord = Coordinator::start(
        BackendChoice::McuSim { q, mode: PruneMode::Dense, div: DivKind::Exact },
        ServeConfig::default(),
    );
    coord.shutdown(); // no requests ever submitted
}

#[test]
fn pjrt_backend_serves_batches() {
    // Artifact- and runtime-gated (same policy as pjrt_roundtrip.rs):
    // skip with a log line when `make artifacts` has not run or the
    // build lacks the `xla` feature — the executor thread would
    // otherwise panic creating its PJRT client.
    if !unit_pruner::runtime::pjrt_available() {
        eprintln!("[pjrt_backend_serves_batches] skipping: built without the `xla` feature");
        return;
    }
    let store = ArtifactStore::discover();
    if !store.dir.join(".stamp").is_file() {
        eprintln!(
            "[pjrt_backend_serves_batches] skipping: artifacts missing at {:?} (run `make artifacts`)",
            store.dir
        );
        return;
    }
    let def = zoo("mnist");
    let params = Params::random(&def, 23);
    let ds = mnist_like::generate(10, Sizes { train: 4, val: 4, test: 16 });
    let coord = Coordinator::start(
        BackendChoice::Pjrt {
            model: "mnist".into(),
            params,
            t_vec: vec![0.0; 3],
            fat_t: 0.0,
        },
        ServeConfig {
            workers: 1,
            max_batch: 8,
            max_wait: Duration::from_millis(5),
            ..Default::default()
        },
    );
    let rxs: Vec<_> = (0..16).map(|i| coord.submit(ds.test.sample(i).to_vec())).collect();
    for rx in rxs {
        let resp = rx.recv_timeout(Duration::from_secs(120)).unwrap();
        assert_eq!(resp.logits.len(), 10);
        assert!(resp.logits.iter().all(|v| v.is_finite()));
    }
    let snap = coord.metrics.snapshot();
    assert_eq!(snap.served, 16);
    assert!(snap.mean_batch > 1.0, "batching never engaged: {}", snap.mean_batch);
    coord.shutdown();
}
