//! Integration: the serving coordinator end-to-end — no request lost,
//! FIFO batching, correct predictions vs direct engine calls, clean
//! shutdown under load, and the PJRT backend (artifact-gated).

use std::time::Duration;

use unit_pruner::approx::{DivExact, DivKind};
use unit_pruner::coordinator::{BackendChoice, Coordinator, ServeConfig};
use unit_pruner::data::{mnist_like, Sizes};
use unit_pruner::engine::{infer, EngineConfig, PruneMode, QModel};
use unit_pruner::models::{zoo, Params};
use unit_pruner::pruning::Thresholds;
use unit_pruner::runtime::ArtifactStore;

fn setup() -> (QModel, unit_pruner::data::Dataset) {
    let def = zoo("mnist");
    let params = Params::random(&def, 21);
    let th = Thresholds::uniform(3, 0.2);
    let q = QModel::quantize(&def, &params).with_thresholds(&th);
    let ds = mnist_like::generate(9, Sizes { train: 4, val: 4, test: 24 });
    (q, ds)
}

#[test]
fn coordinator_matches_direct_engine_calls() {
    let (q, ds) = setup();
    let coord = Coordinator::start(
        BackendChoice::McuSim { q: q.clone(), mode: PruneMode::Unit, div: DivKind::Exact },
        ServeConfig { workers: 2, ..Default::default() },
    );
    let rxs: Vec<_> = (0..ds.test.len()).map(|i| coord.submit(ds.test.sample(i).to_vec())).collect();
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx.recv().unwrap();
        let direct = infer(&q, &q.quantize_input(ds.test.sample(i)), &EngineConfig::unit(&DivExact));
        assert_eq!(resp.predicted, direct.argmax(), "sample {i}");
        assert!((resp.mac_skipped - direct.skip_fraction()).abs() < 1e-12);
    }
    coord.shutdown();
}

#[test]
fn hundreds_of_requests_none_lost() {
    let (q, ds) = setup();
    let coord = Coordinator::start(
        BackendChoice::McuSim { q, mode: PruneMode::Unit, div: DivKind::Shift },
        ServeConfig { workers: 4, ..Default::default() },
    );
    let n = 300usize;
    let rxs: Vec<_> = (0..n).map(|i| coord.submit(ds.test.sample(i % ds.test.len()).to_vec())).collect();
    let mut ids = std::collections::HashSet::new();
    for rx in rxs {
        let resp = rx.recv_timeout(Duration::from_secs(60)).unwrap();
        assert!(ids.insert(resp.id), "duplicate response id {}", resp.id);
    }
    assert_eq!(ids.len(), n);
    let snap = coord.metrics.snapshot();
    assert_eq!(snap.served, n as u64);
    coord.shutdown();
}

#[test]
fn shutdown_with_empty_queue_is_clean() {
    let (q, _ds) = setup();
    let coord = Coordinator::start(
        BackendChoice::McuSim { q, mode: PruneMode::Dense, div: DivKind::Exact },
        ServeConfig::default(),
    );
    coord.shutdown(); // no requests ever submitted
}

#[test]
fn pjrt_backend_serves_batches() {
    // Artifact- and runtime-gated (same policy as pjrt_roundtrip.rs):
    // skip with a log line when `make artifacts` has not run or the
    // build lacks the `xla` feature — the executor thread would
    // otherwise panic creating its PJRT client.
    if !unit_pruner::runtime::pjrt_available() {
        eprintln!("[pjrt_backend_serves_batches] skipping: built without the `xla` feature");
        return;
    }
    let store = ArtifactStore::discover();
    if !store.dir.join(".stamp").is_file() {
        eprintln!(
            "[pjrt_backend_serves_batches] skipping: artifacts missing at {:?} (run `make artifacts`)",
            store.dir
        );
        return;
    }
    let def = zoo("mnist");
    let params = Params::random(&def, 23);
    let ds = mnist_like::generate(10, Sizes { train: 4, val: 4, test: 16 });
    let coord = Coordinator::start(
        BackendChoice::Pjrt {
            model: "mnist".into(),
            params,
            t_vec: vec![0.0; 3],
            fat_t: 0.0,
        },
        ServeConfig { workers: 1, max_batch: 8, max_wait: Duration::from_millis(5) },
    );
    let rxs: Vec<_> = (0..16).map(|i| coord.submit(ds.test.sample(i).to_vec())).collect();
    for rx in rxs {
        let resp = rx.recv_timeout(Duration::from_secs(120)).unwrap();
        assert_eq!(resp.logits.len(), 10);
        assert!(resp.logits.iter().all(|v| v.is_finite()));
    }
    let snap = coord.metrics.snapshot();
    assert_eq!(snap.served, 16);
    assert!(snap.mean_batch > 1.0, "batching never engaged: {}", snap.mean_batch);
    coord.shutdown();
}
