//! Cross-layer integration: the AOT HLO artifacts (Layer 1 Pallas
//! kernels inside the Layer 2 JAX graphs) must load through PJRT and
//! agree numerically with the Rust float engine.
//!
//! Requires `make artifacts` (the Makefile's `test` target guarantees
//! it).

use unit_pruner::models::{zoo, Params};
use unit_pruner::nn::{forward, ForwardOpts};
use unit_pruner::runtime::{try_cpu, ArtifactStore, Runtime};

/// Artifact- and runtime-gated: these tests validate the PJRT bridge,
/// which needs both the `xla` feature and a `make artifacts` run. In
/// environments without either (e.g. the offline CI image) they skip
/// with a log line instead of failing — the pure-Rust engine tests
/// provide the coverage there.
fn gate(name: &str) -> Option<(ArtifactStore, Runtime)> {
    let store = ArtifactStore::discover();
    if !store.dir.join(".stamp").is_file() {
        eprintln!("[{name}] skipping: artifacts missing at {:?} (run `make artifacts`)", store.dir);
        return None;
    }
    let rt = try_cpu(name)?;
    Some((store, rt))
}

#[test]
fn fwd_artifact_matches_rust_float_engine_dense_and_pruned() {
    let Some((store, rt)) = gate("fwd_artifact_matches_rust_float_engine_dense_and_pruned")
    else {
        return;
    };
    // mnist + cifar cover both conv configs; kws exercised in the e2e
    // example (its pallas linear HLO is big, keep test time bounded).
    for model in ["mnist", "cifar"] {
        let def = zoo(model);
        let params = Params::random(&def, 11);
        let exe = store.load_fwd(&rt, model, 1).unwrap();
        let flat = params.flat_order();
        // Dense (T=0) and pruned (T=0.15) must both match.
        for t in [0.0f32, 0.15] {
            let t_vec = vec![t; def.layers.len()];
            let fat = [0.0f32];
            let x: Vec<f32> = (0..def.input_len())
                .map(|i| (((i * 37) % 41) as f32 - 20.0) / 13.0)
                .collect();
            let mut args = flat.clone();
            args.push(&x);
            args.push(&t_vec);
            args.push(&fat);
            let got = &exe.run_f32(&args).unwrap()[0];
            let (want, _) =
                forward(&def, &params, &x, &ForwardOpts { t_vec: t_vec.clone(), fat_t: 0.0 });
            assert_eq!(got.len(), want.len());
            for (a, b) in got.iter().zip(&want) {
                assert!(
                    (a - b).abs() < 1e-3,
                    "{model} t={t}: pjrt {a} vs rust {b}"
                );
            }
        }
    }
}

#[test]
fn fwd_artifact_fatrelu_threshold_respected() {
    let Some((store, rt)) = gate("fwd_artifact_fatrelu_threshold_respected") else {
        return;
    };
    let def = zoo("mnist");
    let params = Params::random(&def, 13);
    let exe = store.load_fwd(&rt, "mnist", 1).unwrap();
    let flat = params.flat_order();
    let t_vec = vec![0.0f32; 3];
    let x: Vec<f32> = (0..def.input_len()).map(|i| ((i % 11) as f32 - 5.0) / 5.0).collect();
    let run = |fat_t: f32| {
        let fat = [fat_t];
        let mut args = flat.clone();
        args.push(&x);
        args.push(&t_vec);
        args.push(&fat);
        exe.run_f32(&args).unwrap()[0].clone()
    };
    let plain = run(0.0);
    let fat = run(0.5);
    // FATReLU changes the result (some activations get truncated)…
    assert_ne!(plain, fat);
    // …and matches the Rust engine under the same cut-off.
    let (want, _) = forward(&def, &params, &x, &ForwardOpts { t_vec, fat_t: 0.5 });
    for (a, b) in fat.iter().zip(&want) {
        assert!((a - b).abs() < 1e-3, "pjrt {a} vs rust {b}");
    }
}

#[test]
fn batch8_artifact_consistent_with_batch1() {
    let Some((store, rt)) = gate("batch8_artifact_consistent_with_batch1") else {
        return;
    };
    let def = zoo("mnist");
    let params = Params::random(&def, 17);
    let e1 = store.load_fwd(&rt, "mnist", 1).unwrap();
    let e8 = store.load_fwd(&rt, "mnist", 8).unwrap();
    let flat = params.flat_order();
    let t_vec = vec![0.05f32; 3];
    let fat = [0.0f32];
    let xs: Vec<Vec<f32>> = (0..8)
        .map(|s| {
            (0..def.input_len())
                .map(|i| (((i + 97 * s) % 23) as f32 - 11.0) / 9.0)
                .collect()
        })
        .collect();
    let bx: Vec<f32> = xs.iter().flatten().copied().collect();
    let mut args8 = flat.clone();
    args8.push(&bx);
    args8.push(&t_vec);
    args8.push(&fat);
    let out8 = &e8.run_f32(&args8).unwrap()[0];
    for (s, x) in xs.iter().enumerate() {
        let mut args1 = flat.clone();
        args1.push(x);
        args1.push(&t_vec);
        args1.push(&fat);
        let out1 = &e1.run_f32(&args1).unwrap()[0];
        for (j, v) in out1.iter().enumerate() {
            let v8 = out8[s * def.classes + j];
            assert!((v - v8).abs() < 1e-4, "sample {s} logit {j}: {v} vs {v8}");
        }
    }
}

#[test]
fn manifests_consistent_with_zoo() {
    let store = ArtifactStore::discover();
    if !store.dir.join(".stamp").is_file() {
        eprintln!("[manifests_consistent_with_zoo] skipping: artifacts missing (run `make artifacts`)");
        return;
    }
    for model in unit_pruner::models::MODEL_NAMES {
        let m = store.manifest(model).unwrap();
        m.check_against(&zoo(model)).unwrap();
    }
}
