//! Multi-model serving acceptance tests (PR 7's tentpole).
//!
//! * **routing** — a fleet coordinator hosting two different zoo models
//!   serves each tenant bit-identically to a dedicated single-model
//!   server: the wire-v4 `model` field threads end to end without
//!   perturbing the engine;
//! * **mixed-tenant load** — interleaved batches addressed to both
//!   tenants complete losslessly and in slot order per request;
//! * **fleet budget over the wire** — `SetBudget` at fleet scope moves
//!   every tenant's published scale step in the right direction, and a
//!   model-scoped cap starves only that tenant;
//! * **addressing errors** — an unknown model id answers `Error`
//!   without killing the session;
//! * **version negotiation (satellite regression)** — a frame carrying
//!   an unsupported wire version is answered with a clean `Goodbye`
//!   and an orderly close, not a decode-error hangup.

use std::sync::Arc;
use std::time::Duration;

use unit_pruner::approx::DivKind;
use unit_pruner::control::{calibrated_cache, FleetScheduler, ScaleGrid};
use unit_pruner::coordinator::{BackendChoice, Coordinator, ModelSpec, ServeConfig};
use unit_pruner::data::{by_name, Sizes};
use unit_pruner::engine::{PlanConfig, PruneMode, QModel};
use unit_pruner::models::{zoo, Params};
use unit_pruner::pruning::Thresholds;
use unit_pruner::serve::{wire, Client, Frame, FrameReader, ServeOpts, Server, Status};

const SIZES: Sizes = Sizes { train: 2, val: 4, test: 8 };

fn model_q(name: &str, seed: u64) -> QModel {
    let def = zoo(name);
    let params = Params::random(&def, seed);
    QModel::quantize(&def, &params)
        .with_thresholds(&Thresholds::uniform(def.layers.len(), 0.2))
}

/// Test samples for one zoo model (its own input length — routing a
/// sample to the wrong tenant is a length mismatch and an `Error`).
fn samples(name: &str, seed: u64) -> Vec<Vec<f32>> {
    let ds = by_name(name, seed, SIZES);
    (0..ds.test.len()).map(|i| ds.test.sample(i).to_vec()).collect()
}

fn specs_for(models: &[(&str, u64)]) -> Vec<ModelSpec> {
    models
        .iter()
        .map(|&(name, seed)| ModelSpec {
            name: name.to_string(),
            q: model_q(name, seed),
            mode: PruneMode::Unit,
            div: DivKind::Exact,
        })
        .collect()
}

/// A fleet server with a scheduler dividing `budget_mj`, plus each
/// tenant's calibrated base cost (mean energy at its most expensive
/// step) for budget arithmetic in the tests.
fn fleet_with_scheduler(
    models: &[(&str, u64)],
    budget_mj: f64,
) -> (Server, Arc<FleetScheduler>, Vec<f64>) {
    let specs = specs_for(models);
    let mut tenants = Vec::new();
    let mut base = Vec::new();
    for (spec, &(name, seed)) in specs.iter().zip(models) {
        let ds = by_name(name, seed, SIZES);
        let cal: Vec<Vec<f32>> =
            (0..ds.val.len()).map(|i| ds.val.sample(i).to_vec()).collect();
        let (cache, profile) = calibrated_cache(
            spec.q.clone(),
            PlanConfig::for_mode(PruneMode::Unit, DivKind::Exact),
            ScaleGrid::default_grid(),
            &cal,
        );
        base.push(profile.mean_mj(0));
        tenants.push((cache, profile));
    }
    let coord =
        Coordinator::start_multi(specs, ServeConfig { workers: 2, ..Default::default() });
    let sched = FleetScheduler::install(&coord, tenants, budget_mj).expect("install");
    let server = Server::start(
        coord,
        "127.0.0.1:0",
        ServeOpts { scheduler: Some(Arc::clone(&sched)), ..Default::default() },
    )
    .expect("bind loopback");
    (server, sched, base)
}

fn poll_until(mut f: impl FnMut() -> bool, secs: u64) -> bool {
    let deadline = std::time::Instant::now() + Duration::from_secs(secs);
    while std::time::Instant::now() < deadline {
        if f() {
            return true;
        }
        std::thread::sleep(Duration::from_millis(20));
    }
    f()
}

/// Acceptance: each tenant of a fleet server answers bit-identically
/// to a dedicated single-model server built from the same quantized
/// model — the v4 `model` field selects the pipeline and nothing else.
#[test]
fn fleet_tenants_serve_bit_identical_to_single_model_servers() {
    let models: &[(&str, u64)] = &[("mnist", 41), ("cifar", 42)];
    // Reference: one dedicated server per model.
    let mut reference: Vec<Vec<Vec<f32>>> = Vec::new();
    for &(name, seed) in models {
        let coord = Coordinator::start(
            BackendChoice::McuSim {
                q: model_q(name, seed),
                mode: PruneMode::Unit,
                div: DivKind::Exact,
            },
            ServeConfig { workers: 2, ..Default::default() },
        );
        let server = Server::start(coord, "127.0.0.1:0", ServeOpts::default()).unwrap();
        let client = Client::connect(server.local_addr()).unwrap();
        let mut logits = Vec::new();
        for x in samples(name, seed) {
            let (_id, rx) = client.submit(&x, None).unwrap();
            let ev = rx.recv_timeout(Duration::from_secs(60)).unwrap();
            assert_eq!(ev.status, Status::Ok);
            logits.push(ev.logits);
        }
        assert!(client.goodbye(Duration::from_secs(10)));
        server.shutdown();
        reference.push(logits);
    }
    // Fleet: both models behind one coordinator, no control plane — the
    // per-model default plans are exactly what the dedicated servers
    // compiled.
    let coord = Coordinator::start_multi(
        specs_for(models),
        ServeConfig { workers: 2, ..Default::default() },
    );
    let server = Server::start(coord, "127.0.0.1:0", ServeOpts::default()).unwrap();
    let client = Client::connect(server.local_addr()).unwrap();
    for (m, &(name, seed)) in models.iter().enumerate() {
        for (i, x) in samples(name, seed).iter().enumerate() {
            let (_id, rx) = client.submit_to(m as u32, x, None).unwrap();
            let ev = rx.recv_timeout(Duration::from_secs(60)).unwrap();
            assert_eq!(ev.status, Status::Ok);
            assert_eq!(
                ev.logits, reference[m][i],
                "model {m} sample {i}: fleet logits differ from single-model serving"
            );
        }
    }
    assert!(client.goodbye(Duration::from_secs(10)));
    server.shutdown();
}

/// Acceptance: interleaved batches addressed to both tenants are
/// lossless and slot-ordered per request under concurrent clients.
#[test]
fn mixed_tenant_load_is_lossless_and_slot_ordered() {
    let models: &[(&str, u64)] = &[("mnist", 43), ("cifar", 44)];
    let budget: f64 = 1e12; // generous: allocation plays no part here
    let (server, _sched, _base) = fleet_with_scheduler(models, budget);
    let addr = server.local_addr();
    let handles: Vec<_> = (0..2)
        .map(|c| {
            let pools: Vec<Vec<Vec<f32>>> =
                models.iter().map(|&(n, s)| samples(n, s)).collect();
            std::thread::spawn(move || {
                let client = Client::connect(addr).unwrap();
                let mut done = 0usize;
                for round in 0..3 {
                    // One in-flight batch per tenant, interleaved.
                    let rxs: Vec<_> = pools
                        .iter()
                        .enumerate()
                        .map(|(m, pool)| {
                            let xs: Vec<Vec<f32>> = (0..pool.len())
                                .map(|i| pool[(i + round + c) % pool.len()].clone())
                                .collect();
                            let n = xs.len();
                            let (_id, rx) =
                                client.submit_batch_to(m as u32, &xs, None).unwrap();
                            (rx, n)
                        })
                        .collect();
                    for (rx, n) in rxs {
                        for slot in 0..n {
                            let ev = rx.recv_timeout(Duration::from_secs(120)).unwrap();
                            assert_eq!(ev.status, Status::Ok);
                            assert_eq!(ev.slot as usize, slot, "sub-replies out of order");
                            done += 1;
                        }
                    }
                }
                client.goodbye(Duration::from_secs(10));
                done
            })
        })
        .collect();
    let total: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
    let snap = server.metrics().snapshot();
    assert_eq!(snap.served, total as u64, "samples lost under mixed-tenant load");
    assert_eq!(snap.rejected + snap.expired + snap.cancelled + snap.failed, 0);
    server.shutdown();
}

/// Acceptance: fleet-scoped `SetBudget` re-solves the global
/// allocation — starving pushes every tenant to its cheapest step,
/// relief buys everyone back down — and a model-scoped cap starves
/// only that tenant.
#[test]
fn fleet_budget_moves_published_steps_over_the_wire() {
    let models: &[(&str, u64)] = &[("mnist", 51), ("cifar", 52)];
    let (server, _sched, base) = fleet_with_scheduler(models, 1.0);
    // Generous: both tenants' most expensive steps are affordable.
    let generous = base.iter().sum::<f64>() * 2.0;
    let client = Client::connect(server.local_addr()).unwrap();
    let probe = client.query_stats(Duration::from_secs(10)).unwrap();
    assert_eq!(probe.models_loaded, 2);
    let last = probe.steps_total - 1;

    let step_of = |m: u32| {
        client.query_model_stats(m, Duration::from_secs(10)).unwrap().step
    };
    // Relief to generous: everyone buys down to the most expensive
    // (most accurate) step.
    client.set_budget(generous, Duration::from_secs(10)).unwrap();
    assert!(
        poll_until(|| step_of(0) == 0 && step_of(1) == 0, 30),
        "generous fleet budget did not buy both tenants down (steps {}/{})",
        step_of(0),
        step_of(1)
    );
    // Starvation: no buy-down move is affordable; everyone stays at
    // the cheapest step.
    client.set_budget(1e-9, Duration::from_secs(10)).unwrap();
    assert!(
        poll_until(|| step_of(0) == last && step_of(1) == last, 30),
        "starved fleet budget did not push both tenants up (steps {}/{})",
        step_of(0),
        step_of(1)
    );
    // Relief again: the walk is reversible.
    client.set_budget(generous, Duration::from_secs(10)).unwrap();
    assert!(
        poll_until(|| step_of(0) == 0 && step_of(1) == 0, 30),
        "fleet relief did not restore the allocation"
    );
    // Model-scoped cap: tenant 0 is pinned to affordable steps only,
    // tenant 1 keeps its full allocation. The cap is far below tenant
    // 0's cheapest isotonized cost, so it sits at the last step.
    let reply = client
        .set_model_budget(0, base[0] * 1e-9, Duration::from_secs(10))
        .unwrap();
    assert_eq!(reply.model, 0, "model-scoped reply must report that tenant");
    assert!(
        poll_until(|| step_of(0) == last && step_of(1) == 0, 30),
        "tenant cap did not starve exactly the capped tenant (steps {}/{})",
        step_of(0),
        step_of(1)
    );
    assert!(client.goodbye(Duration::from_secs(10)));
    server.shutdown();
}

/// An unknown model id answers `Error` without killing the session.
#[test]
fn unknown_model_id_answers_error_and_session_survives() {
    let models: &[(&str, u64)] = &[("mnist", 61), ("cifar", 62)];
    let coord = Coordinator::start_multi(
        specs_for(models),
        ServeConfig { workers: 2, ..Default::default() },
    );
    let server = Server::start(coord, "127.0.0.1:0", ServeOpts::default()).unwrap();
    let client = Client::connect(server.local_addr()).unwrap();
    let xs = samples("mnist", 61);
    let (_id, rx) = client.submit_to(7, &xs[0], None).unwrap();
    let ev = rx.recv_timeout(Duration::from_secs(30)).unwrap();
    assert_eq!(ev.status, Status::Error, "unknown model must answer Error");
    // A wrong-length sample (mnist data to the kws tenant) is the same
    // protocol error, not a worker crash.
    let (_id, rx) = client.submit_to(1, &xs[0], None).unwrap();
    let ev = rx.recv_timeout(Duration::from_secs(30)).unwrap();
    assert_eq!(ev.status, Status::Error, "length mismatch must answer Error");
    // The session survives both.
    let (_id, rx) = client.submit_to(0, &xs[0], None).unwrap();
    assert_eq!(rx.recv_timeout(Duration::from_secs(60)).unwrap().status, Status::Ok);
    assert!(client.goodbye(Duration::from_secs(10)));
    server.shutdown();
}

/// Satellite regression: an unsupported wire version is refused with a
/// clean `Goodbye` and an orderly close — not a decode-error hangup.
#[test]
fn unsupported_wire_version_gets_goodbye_then_clean_close() {
    use std::io::{Read, Write};

    let coord = Coordinator::start(
        BackendChoice::McuSim {
            q: model_q("mnist", 71),
            mode: PruneMode::Unit,
            div: DivKind::Exact,
        },
        ServeConfig { workers: 1, ..Default::default() },
    );
    let server = Server::start(coord, "127.0.0.1:0", ServeOpts::default()).unwrap();

    // A structurally valid Ping whose version field claims 99: patch
    // the version bytes and re-seal the CRC so only the version check
    // can reject it.
    let mut bytes = wire::encode(&Frame::Ping { id: 9 });
    bytes[8..10].copy_from_slice(&99u16.to_le_bytes());
    let n = bytes.len();
    let crc = wire::crc32(&bytes[4..n - 4]);
    bytes[n - 4..].copy_from_slice(&crc.to_le_bytes());

    let mut stream = std::net::TcpStream::connect(server.local_addr()).unwrap();
    stream.write_all(&bytes).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let mut reader = FrameReader::new();
    let mut got = Vec::new();
    let mut buf = [0u8; 4096];
    let clean_eof = loop {
        match stream.read(&mut buf) {
            Ok(0) => break true,
            Ok(k) => {
                reader.feed(&buf[..k]);
                while let Some(f) = reader.next().expect("server reply must stay framed") {
                    got.push(f);
                }
            }
            Err(e) => panic!("read after bad-version frame failed: {e}"),
        }
    };
    assert_eq!(got, vec![Frame::Goodbye], "expected exactly one Goodbye");
    assert!(clean_eof, "connection must close cleanly after the Goodbye");
    server.shutdown();
}
