//! Adaptive-serving acceptance tests (ISSUE 4):
//!
//! * a loopback client streams requests while the budget is lowered
//!   mid-run; the governor raises the scale, the plan cache serves the
//!   new scale without recompiling on repeat visits (hit counter
//!   asserted), replies stay lossless/ordered, and logits at each
//!   scale step are bit-identical to a single-shot run compiled at
//!   that scale;
//! * parked-frame admission (satellite): window-overflow requests wait
//!   in the park queue and are admitted FIFO as credits return, with
//!   deadlines still enforced from frame receipt.

use std::sync::Arc;
use std::time::Duration;

use unit_pruner::approx::DivKind;
use unit_pruner::control::{Governor, KeepProfile, PlanCache, ScaleGrid};
use unit_pruner::coordinator::{BackendChoice, Coordinator, Placement, ServeConfig};
use unit_pruner::data::{mnist_like, Sizes};
use unit_pruner::engine::{PlanConfig, PlannedModel, PruneMode, QModel};
use unit_pruner::models::{zoo, Params};
use unit_pruner::pruning::Thresholds;
use unit_pruner::serve::{Client, ServeOpts, Server, SessionCfg, Status, WHOLE_REQUEST};

fn setup_q(seed: u64) -> QModel {
    let def = zoo("mnist");
    let params = Params::random(&def, seed);
    QModel::quantize(&def, &params).with_thresholds(&Thresholds::uniform(3, 0.15))
}

struct AdaptiveRig {
    server: Server,
    cache: Arc<PlanCache>,
    q: QModel,
}

fn start_adaptive_with(
    seed: u64,
    workers: usize,
    budget_mj: f64,
    calibrate: bool,
) -> AdaptiveRig {
    let q = setup_q(seed);
    let coord = Coordinator::start(
        BackendChoice::McuSim { q: q.clone(), mode: PruneMode::Unit, div: DivKind::Exact },
        ServeConfig { workers, placement: Placement::CostWeighted, ..Default::default() },
    );
    let cache = Arc::new(PlanCache::new(
        q.clone(),
        PlanConfig::unit(DivKind::Exact),
        ScaleGrid::default_grid(),
    ));
    // With calibration the profile measurement warms every grid step
    // (misses only on eviction); without it the cache starts cold past
    // the seeded step, so budget swings exercise the governor's
    // background compile thread over the wire.
    let profile = if calibrate {
        let def = zoo("mnist");
        let cal: Vec<Vec<f32>> = (0..3)
            .map(|s| {
                (0..def.input_len())
                    .map(|i| (((i * 7 + s * 3) % 21) as f32 - 10.0) / 8.0)
                    .collect()
            })
            .collect();
        Some(Arc::new(KeepProfile::measure(&cache, &cal)))
    } else {
        None
    };
    let governor = Governor::install(&coord, Arc::clone(&cache), profile, budget_mj)
        .expect("governor installs on mcu backend");
    let server = Server::start(
        coord,
        "127.0.0.1:0",
        ServeOpts { max_conns: 8, governor: Some(governor), ..Default::default() },
    )
    .expect("bind loopback");
    AdaptiveRig { server, cache, q }
}

fn start_adaptive(seed: u64, workers: usize, budget_mj: f64) -> AdaptiveRig {
    start_adaptive_with(seed, workers, budget_mj, true)
}

/// Drive singles until the governor's reported step stabilizes at
/// `target` (saturation under an extreme budget), or panic after a
/// bounded number of requests.
fn drive_until_step(client: &Client, xs: &[Vec<f32>], target: u32, max_requests: usize) {
    for r in 0..max_requests {
        let x = &xs[r % xs.len()];
        let (_id, rx) = client.submit(x, None).unwrap();
        let ev = rx.recv_timeout(Duration::from_secs(60)).unwrap();
        assert_eq!(ev.status, Status::Ok, "warmup request failed");
        let s = client.query_stats(Duration::from_secs(10)).unwrap();
        if s.step == target {
            return;
        }
    }
    let s = client.query_stats(Duration::from_secs(10)).unwrap();
    panic!("step never reached {target} within {max_requests} requests (at {})", s.step);
}

/// The ISSUE 4 acceptance test: budget lowered mid-run → scale rises,
/// cache-served on repeat, lossless/ordered, bit-identical per step.
#[test]
fn budget_swing_end_to_end_is_cache_served_and_bit_identical() {
    let rig = start_adaptive(51, 2, 1e9);
    let grid = ScaleGrid::default_grid();
    let max_step = (grid.len() - 1) as u32;
    let client = Client::connect(rig.server.local_addr()).unwrap();
    let probe = client.query_stats(Duration::from_secs(10)).unwrap();
    assert!(probe.adaptive(), "governor not reported over the wire");
    assert_eq!(probe.steps_total as usize, grid.len());

    let ds = mnist_like::generate(21, Sizes { train: 2, val: 2, test: 10 });
    let xs: Vec<Vec<f32>> = (0..ds.test.len()).map(|i| ds.test.sample(i).to_vec()).collect();

    // A plan compiled OUTSIDE the serving stack at a given step — the
    // single-shot reference the wire replies must match bit-for-bit.
    let reference = |step: u32| {
        PlannedModel::compile(
            &rig.q,
            PlanConfig { t_scale_q8: grid.q8(step as usize), ..PlanConfig::unit(DivKind::Exact) },
        )
    };
    let assert_batch_matches = |step: u32| {
        let reference = reference(step);
        let mut scratch = reference.new_scratch();
        let (_id, rx) = client.submit_batch(&xs, None).unwrap();
        for (slot, x) in xs.iter().enumerate() {
            let ev = rx.recv_timeout(Duration::from_secs(60)).unwrap();
            assert_eq!(ev.status, Status::Ok, "step {step} slot {slot}");
            assert_eq!(ev.slot as usize, slot, "step {step}: sub-replies out of order");
            let direct = reference.infer(&reference.quantize_input(x), &mut scratch);
            assert_eq!(
                ev.logits, direct.logits,
                "step {step} slot {slot}: logits differ from single-shot compile"
            );
            assert_eq!(ev.predicted as usize, direct.argmax(), "step {step} slot {slot}");
        }
        // The governor observed the batch under an extreme budget, so
        // the step must not have moved off saturation.
        let s = client.query_stats(Duration::from_secs(10)).unwrap();
        assert_eq!(s.step, step, "step moved mid-batch despite a saturating budget");
        assert_eq!(s.scale_q8, grid.q8(step as usize), "reported scale off-grid");
    };

    // Phase 1 — generous budget: saturate at the minimum step, then a
    // batch must be lossless, ordered, and bit-identical to a fresh
    // compile at that step.
    client.set_budget(1e9, Duration::from_secs(10)).unwrap();
    drive_until_step(&client, &xs, 0, 300);
    assert_batch_matches(0);

    // Phase 2 — budget lowered mid-run to starvation: the governor
    // must raise the scale to the top step; same guarantees there.
    client.set_budget(1e-9, Duration::from_secs(10)).unwrap();
    drive_until_step(&client, &xs, max_step, 600);
    assert_batch_matches(max_step);
    let after_up = client.query_stats(Duration::from_secs(10)).unwrap();
    assert!(after_up.swaps > 0, "no plan swaps during the budget swing");

    // Phase 3 — relief: walk back down. Every step on the way down was
    // compiled on the way up, so the cache must serve the walk hit-only
    // (miss counter frozen, hit counter growing).
    let misses_before = after_up.cache_misses;
    let hits_before = after_up.cache_hits;
    client.set_budget(1e9, Duration::from_secs(10)).unwrap();
    drive_until_step(&client, &xs, 0, 600);
    let s = client.query_stats(Duration::from_secs(10)).unwrap();
    assert_eq!(
        s.cache_misses, misses_before,
        "revisited scale steps were recompiled instead of cache-served"
    );
    assert!(s.cache_hits > hits_before, "walk-down produced no cache hits");
    // Local cache handle agrees with the wire-reported counters.
    assert_eq!(rig.cache.hits(), s.cache_hits);
    assert_eq!(rig.cache.misses(), s.cache_misses);

    assert!(client.goodbye(Duration::from_secs(10)));
    let snap = rig.server.metrics().snapshot();
    assert_eq!(snap.rejected + snap.expired + snap.cancelled, 0, "lossy run");
    rig.server.shutdown();
}

/// Cold cache + starved budget over the wire: misses are compiled by
/// the governor's background thread while the swap path keeps serving
/// (every request completes), the pool still reaches the top step, and
/// the compile-thread health counters surface through the Stats frame.
#[test]
fn cold_cache_misses_compile_in_background_without_stalling_serving() {
    let rig = start_adaptive_with(56, 2, 1e9, false);
    let grid = ScaleGrid::default_grid();
    let max_step = (grid.len() - 1) as u32;
    let client = Client::connect(rig.server.local_addr()).unwrap();
    assert!(rig.cache.len() <= 1, "cold rig must not pre-warm the grid");

    let ds = mnist_like::generate(23, Sizes { train: 2, val: 2, test: 8 });
    let xs: Vec<Vec<f32>> = (0..ds.test.len()).map(|i| ds.test.sample(i).to_vec()).collect();
    client.set_budget(1e-9, Duration::from_secs(10)).unwrap();
    // Every request must complete Ok even while compiles are pending —
    // the swap path publishes nearest-resident plans instead of
    // blocking on the cache lock.
    drive_until_step(&client, &xs, max_step, 600);
    let s = client.query_stats(Duration::from_secs(10)).unwrap();
    assert!(s.bg_compiled > 0, "climb produced no background compiles");
    assert!(
        s.bg_compiled >= s.bg_upgrades,
        "upgrade counter exceeds compile counter"
    );
    // Once saturated, the queue drains: the pending gauge returns to 0.
    let deadline = std::time::Instant::now() + Duration::from_secs(30);
    loop {
        let s = client.query_stats(Duration::from_secs(10)).unwrap();
        if s.bg_pending == 0 {
            break;
        }
        assert!(std::time::Instant::now() < deadline, "compile queue never drained");
        std::thread::sleep(Duration::from_millis(20));
    }
    // The coordinator metrics mirror the wire-reported counters (the
    // mirror is published at the end of each compile-loop iteration,
    // so allow it a moment to catch up to the governor's own count).
    let s = client.query_stats(Duration::from_secs(10)).unwrap();
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    loop {
        let snap = rig.server.metrics().snapshot();
        if snap.bg_compiled == s.bg_compiled && snap.bg_upgrades == s.bg_upgrades {
            break;
        }
        assert!(
            std::time::Instant::now() < deadline,
            "metrics mirror never converged: {}c/{}u vs wire {}c/{}u",
            snap.bg_compiled,
            snap.bg_upgrades,
            s.bg_compiled,
            s.bg_upgrades
        );
        std::thread::sleep(Duration::from_millis(10));
    }
    let snap = rig.server.metrics().snapshot();
    assert_eq!(snap.rejected + snap.expired + snap.cancelled, 0, "lossy run");
    assert!(client.goodbye(Duration::from_secs(10)));
    rig.server.shutdown();
}

/// A server without a governor answers admin frames with the disabled
/// shape instead of an error.
#[test]
fn set_budget_without_governor_reports_disabled() {
    let q = setup_q(52);
    let coord = Coordinator::start(
        BackendChoice::McuSim { q, mode: PruneMode::Unit, div: DivKind::Shift },
        ServeConfig { workers: 1, ..Default::default() },
    );
    let server =
        Server::start(coord, "127.0.0.1:0", ServeOpts::default()).expect("bind loopback");
    let client = Client::connect(server.local_addr()).unwrap();
    let s = client.set_budget(5.0, Duration::from_secs(10)).unwrap();
    assert!(!s.adaptive());
    assert_eq!(s.scale_q8, 0);
    drop(client);
    server.shutdown();
}

// ---------------------------------------------------------------------------
// Parked-frame admission (satellite)

fn start_parked_with_bytes(
    seed: u64,
    workers: usize,
    window: usize,
    park: usize,
    park_bytes: usize,
) -> (Server, Vec<Vec<f32>>) {
    let q = setup_q(seed);
    let coord = Coordinator::start(
        BackendChoice::McuSim { q, mode: PruneMode::Unit, div: DivKind::Shift },
        ServeConfig { workers, ..Default::default() },
    );
    let server = Server::start(
        coord,
        "127.0.0.1:0",
        ServeOpts {
            max_conns: 4,
            session: SessionCfg {
                max_inflight: window,
                park,
                park_bytes,
                ..Default::default()
            },
            ..Default::default()
        },
    )
    .expect("bind loopback");
    let ds = mnist_like::generate(22, Sizes { train: 2, val: 2, test: 8 });
    let xs = (0..ds.test.len()).map(|i| ds.test.sample(i).to_vec()).collect();
    (server, xs)
}

fn start_parked(
    seed: u64,
    workers: usize,
    window: usize,
    park: usize,
) -> (Server, Vec<Vec<f32>>) {
    start_parked_with_bytes(seed, workers, window, park, 0)
}

/// Overflow requests are parked (no Rejected frame), admitted FIFO on
/// credit return, and complete normally; overflow past the park bound
/// still rejects.
#[test]
fn parked_overflow_admitted_on_credit_return() {
    let (server, xs) = start_parked(53, 1, 1, 3);
    let client = Client::connect(server.local_addr()).unwrap();
    // Occupy the window-of-1 with a long batch on the single worker.
    let big: Vec<Vec<f32>> = (0..48).map(|i| xs[i % xs.len()].clone()).collect();
    let (_ib, rx_big) = client.submit_batch(&big, None).unwrap();
    // Three singles overflow the window into the park queue…
    let parked_rxs: Vec<_> =
        (0..3).map(|i| client.submit(&xs[i], None).unwrap().1).collect();
    // …and a fourth overflows the park bound: immediate rejection.
    let (_ir, rx_rej) = client.submit(&xs[3], None).unwrap();
    let ev = rx_rej.recv_timeout(Duration::from_secs(30)).unwrap();
    assert_eq!((ev.status, ev.slot), (Status::Rejected, WHOLE_REQUEST));
    // The batch drains; every parked request is then admitted and
    // completes with a real result — no client-side retry loop.
    for slot in 0..big.len() {
        let ev = rx_big.recv_timeout(Duration::from_secs(120)).unwrap();
        assert_eq!((ev.status, ev.slot as usize), (Status::Ok, slot));
    }
    for (i, rx) in parked_rxs.iter().enumerate() {
        let ev = rx.recv_timeout(Duration::from_secs(120)).unwrap();
        assert_eq!(ev.status, Status::Ok, "parked request {i} failed");
        assert_eq!(ev.slot, 0);
    }
    let snap = server.metrics().snapshot();
    assert_eq!(snap.parked, 3, "park admissions miscounted");
    assert_eq!(snap.rejected, 1, "park-bound overflow must still reject");
    assert_eq!(snap.served, big.len() as u64 + 3);
    assert!(client.goodbye(Duration::from_secs(10)));
    server.shutdown();
}

/// The park queue's byte budget (ROADMAP follow-up: parked payloads
/// are held decoded): a single that fits the entry cap but would push
/// the queue's decoded bytes past `park_bytes` is rejected, while one
/// that fits both caps parks, is admitted on credit return, and
/// completes — and after the queue drains the freed budget admits new
/// overflow again.
#[test]
fn park_byte_budget_rejects_overflow_the_count_cap_would_admit() {
    // One mnist f32 sample = 784 * 4 = 3136 decoded bytes. Budget of
    // 4000 bytes holds exactly one parked single; the entry cap of 4
    // would happily hold more.
    let sample_bytes = 784 * 4;
    let (server, xs) = start_parked_with_bytes(57, 1, 1, 4, sample_bytes + 100);
    let client = Client::connect(server.local_addr()).unwrap();
    // Occupy the window-of-1 with a long batch on the single worker.
    let big: Vec<Vec<f32>> = (0..48).map(|i| xs[i % xs.len()].clone()).collect();
    let (_ib, rx_big) = client.submit_batch(&big, None).unwrap();
    // First overflow single: fits count (1 ≤ 4) and bytes — parks.
    let (_ip, rx_parked) = client.submit(&xs[0], None).unwrap();
    // Second overflow single: count cap has room (2 ≤ 4) but the byte
    // budget is spent — immediate rejection.
    let (_ir, rx_rej) = client.submit(&xs[1], None).unwrap();
    let ev = rx_rej.recv_timeout(Duration::from_secs(30)).unwrap();
    assert_eq!(
        (ev.status, ev.slot),
        (Status::Rejected, WHOLE_REQUEST),
        "byte-budget overflow must reject even with count-cap room"
    );
    // The batch drains; the parked single is admitted and completes.
    for slot in 0..big.len() {
        let ev = rx_big.recv_timeout(Duration::from_secs(120)).unwrap();
        assert_eq!((ev.status, ev.slot as usize), (Status::Ok, slot));
    }
    let ev = rx_parked.recv_timeout(Duration::from_secs(120)).unwrap();
    assert_eq!(ev.status, Status::Ok, "within-budget parked request failed");
    // The budget was freed by admission: a fresh overflow parks again
    // (no stuck byte accounting). Submit a quick second batch to force
    // overflow, then the probe single.
    let big2: Vec<Vec<f32>> = (0..16).map(|i| xs[i % xs.len()].clone()).collect();
    let (_ib2, rx_big2) = client.submit_batch(&big2, None).unwrap();
    let (_ip2, rx_parked2) = client.submit(&xs[2], None).unwrap();
    for slot in 0..big2.len() {
        let ev = rx_big2.recv_timeout(Duration::from_secs(120)).unwrap();
        assert_eq!((ev.status, ev.slot as usize), (Status::Ok, slot));
    }
    let ev = rx_parked2.recv_timeout(Duration::from_secs(120)).unwrap();
    assert_eq!(ev.status, Status::Ok, "byte budget not released after drain");
    let snap = server.metrics().snapshot();
    assert_eq!(snap.parked, 2, "park admissions miscounted");
    assert_eq!(snap.rejected, 1);
    assert!(client.goodbye(Duration::from_secs(10)));
    server.shutdown();
}

/// A deadline keeps running while parked: a request that cannot be
/// admitted before its deadline comes back `Expired`, not `Ok`.
#[test]
fn parked_request_deadline_runs_from_receipt() {
    let (server, xs) = start_parked(54, 1, 1, 4);
    let client = Client::connect(server.local_addr()).unwrap();
    let big: Vec<Vec<f32>> = (0..96).map(|i| xs[i % xs.len()].clone()).collect();
    let (_ib, rx_big) = client.submit_batch(&big, None).unwrap();
    // Parked behind ~96 samples on one worker with a 1 ms deadline:
    // expired long before a credit returns.
    let (_ie, rx_exp) = client.submit(&xs[0], Some(Duration::from_millis(1))).unwrap();
    let ev = rx_exp.recv_timeout(Duration::from_secs(60)).unwrap();
    assert_eq!((ev.status, ev.slot), (Status::Expired, WHOLE_REQUEST));
    for slot in 0..big.len() {
        let ev = rx_big.recv_timeout(Duration::from_secs(120)).unwrap();
        assert_eq!((ev.status, ev.slot as usize), (Status::Ok, slot));
    }
    let snap = server.metrics().snapshot();
    assert_eq!(snap.expired, 1);
    assert_eq!(snap.served, big.len() as u64, "the expired request must not be served");
    assert!(client.goodbye(Duration::from_secs(10)));
    server.shutdown();
}

/// Draining a session with parked work answers it `Rejected` before
/// the goodbye — parked frames are never silently dropped.
#[test]
fn drain_rejects_parked_work() {
    let (server, xs) = start_parked(55, 1, 1, 4);
    let client = Client::connect(server.local_addr()).unwrap();
    let big: Vec<Vec<f32>> = (0..64).map(|i| xs[i % xs.len()].clone()).collect();
    let (_ib, rx_big) = client.submit_batch(&big, None).unwrap();
    let (_ip, rx_parked) = client.submit(&xs[0], None).unwrap();
    // Shut the server down while the single sits parked. The drain
    // completes the in-flight batch, then rejects the parked frame.
    let t = std::thread::spawn(move || server.shutdown());
    for slot in 0..big.len() {
        let ev = rx_big.recv_timeout(Duration::from_secs(120)).unwrap();
        assert_eq!((ev.status, ev.slot as usize), (Status::Ok, slot));
    }
    let ev = rx_parked.recv_timeout(Duration::from_secs(60)).unwrap();
    assert_eq!((ev.status, ev.slot), (Status::Rejected, WHOLE_REQUEST));
    t.join().expect("shutdown panicked");
}
