//! Integration: the PJRT training path — the exported train-step HLO
//! must load, run, and reduce the loss on synthetic data (artifact-
//! gated; `make test` builds artifacts first).

use unit_pruner::data::{by_name, Sizes};
use unit_pruner::nn::ForwardOpts;
use unit_pruner::runtime::{try_cpu, ArtifactStore};
use unit_pruner::train::{evaluate_float, train, TrainConfig};

#[test]
fn train_step_artifact_reduces_loss_and_lifts_accuracy() {
    // Artifact- and runtime-gated (see pjrt_roundtrip.rs): skips with a
    // log line when `make artifacts` has not run or the build lacks the
    // `xla` feature.
    let store = ArtifactStore::discover();
    if !store.dir.join(".stamp").is_file() {
        eprintln!(
            "[train_smoke] skipping: artifacts missing at {:?} (run `make artifacts`)",
            store.dir
        );
        return;
    }
    let Some(rt) = try_cpu("train_smoke") else {
        return;
    };
    let ds = by_name("mnist", 1234, Sizes { train: 256, val: 32, test: 64 });
    let cfg = TrainConfig { steps: 60, lr: 0.05, seed: 5, log_every: 0, lr_decay: false };
    let (params, losses) = train(&rt, &store, "mnist", &ds, &cfg).unwrap();
    assert_eq!(losses.len(), 60);
    // loss must drop hard on this easy synthetic set
    let head: f32 = losses[..5].iter().sum::<f32>() / 5.0;
    let tail: f32 = losses[55..].iter().sum::<f32>() / 5.0;
    assert!(tail < head * 0.7, "loss did not improve: {head} -> {tail}");
    // trained model beats chance clearly
    let def = unit_pruner::models::zoo("mnist");
    let r = evaluate_float(&def, &params, &ds.test, &ForwardOpts::dense(3), 64);
    assert!(r.accuracy > 0.3, "accuracy after 60 steps: {}", r.accuracy);
}
