//! End-to-end driver (EXPERIMENTS.md §E2E): proves all three layers
//! compose on a real small workload.
//!
//! ```text
//! make artifacts && cargo run --release --example train_and_deploy
//! ```
//!
//! 1. **Train** the Table-1 MNIST model for a few hundred SGD steps by
//!    repeatedly executing the AOT `train` HLO (Layer 2, lowered once by
//!    Python at build time) through PJRT — the loss curve is logged.
//! 2. **Verify** the float forward path: the AOT `fwd` artifact (which
//!    embeds the Layer-1 Pallas kernels) must agree with the Rust float
//!    engine on the trained weights.
//! 3. **Deploy**: quantize to int8/Q8.8, calibrate UnIT thresholds on
//!    the validation split, and run the MCU simulator test-set
//!    evaluation — accuracy, MACs skipped, modeled time and energy,
//!    dense vs UnIT.

use anyhow::Result;
use unit_pruner::approx::DivShift;
use unit_pruner::data::{by_name, Sizes};
use unit_pruner::engine::{infer, EngineConfig, QModel};
use unit_pruner::mcu::EnergyModel;
use unit_pruner::models::zoo;
use unit_pruner::nn::{forward, ForwardOpts};
use unit_pruner::pruning::{calibrate, CalibConfig};
use unit_pruner::runtime::{ArtifactStore, Runtime};
use unit_pruner::train::{train, TrainConfig};
use unit_pruner::util::table::Table;

fn main() -> Result<()> {
    let model = "mnist";
    let rt = Runtime::cpu()?;
    let store = ArtifactStore::discover();
    let def = zoo(model);
    let ds = by_name(model, 42, Sizes::default());

    // --- 1. train through the AOT step artifact -------------------------
    println!("=== 1. training {model} via AOT train-step HLO (PJRT) ===");
    let cfg = TrainConfig { log_every: 40, ..TrainConfig::for_model(model) };
    let (params, losses) = train(&rt, &store, model, &ds, &cfg)?;
    println!("loss curve: start {:.4} -> end {:.4} ({} steps)", losses[0], losses.last().unwrap(), losses.len());

    // --- 2. cross-layer verification ------------------------------------
    println!("\n=== 2. AOT fwd artifact (Pallas kernels) vs Rust float engine ===");
    let fwd_exe = store.load_fwd(&rt, model, 1)?;
    let t_vec = vec![0.1f32; def.layers.len()];
    let fat = [0.0f32];
    let flat: Vec<&[f32]> = params.flat_order();
    let mut max_err = 0f32;
    for i in 0..4 {
        let x = ds.test.sample(i);
        let mut args = flat.clone();
        args.push(x);
        args.push(&t_vec);
        args.push(&fat);
        let got = &fwd_exe.run_f32(&args)?[0];
        let (want, _) =
            forward(&def, &params, x, &ForwardOpts { t_vec: t_vec.clone(), fat_t: 0.0 });
        for (a, b) in got.iter().zip(&want) {
            max_err = max_err.max((a - b).abs());
        }
    }
    println!("max |pjrt - rust| over 4 pruned inferences: {max_err:.2e}");
    anyhow::ensure!(max_err < 1e-3, "cross-layer mismatch");

    // --- 3. quantize + calibrate + deploy to the MCU sim ----------------
    println!("\n=== 3. MCU deployment: dense vs UnIT ===");
    let th = calibrate(&def, &params, &ds.val, &CalibConfig::default());
    println!("thresholds (p20 of |x*w|): {:?}", th.per_layer);
    let q_dense = QModel::quantize(&def, &params);
    let q_unit = q_dense.clone().with_thresholds(&th);
    let energy = EnergyModel::default();
    let n = ds.test.len().min(200);
    let mut table =
        Table::new(vec!["config", "accuracy", "MACs skipped", "time s", "energy mJ"]);
    for (name, q, cfg) in [
        ("dense", &q_dense, EngineConfig::dense(&DivShift)),
        ("UnIT", &q_unit, EngineConfig::unit(&DivShift)),
    ] {
        let mut hits = 0;
        let mut skip = 0.0;
        let mut secs = 0.0;
        let mut mj = 0.0;
        for i in 0..n {
            let out = infer(q, &q.quantize_input(ds.test.sample(i)), &cfg);
            hits += (out.argmax() == ds.test.y[i]) as usize;
            skip += out.skip_fraction();
            secs += out.ledger.secs();
            mj += out.ledger.millijoules(&energy);
        }
        let nf = n as f64;
        table.row(vec![
            name.to_string(),
            format!("{:.2}%", 100.0 * hits as f64 / nf),
            format!("{:.2}%", 100.0 * skip / nf),
            format!("{:.3}", secs / nf),
            format!("{:.3}", mj / nf),
        ]);
    }
    println!("{}", table.render());
    println!("all three layers compose: Pallas kernel -> JAX model -> AOT HLO -> rust runtime -> MCU engine");
    Ok(())
}
