//! Quickstart: the UnIT public API in ~60 lines, no artifacts needed.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```
//!
//! Builds a Table-1 model with random weights, calibrates UnIT
//! thresholds on a synthetic validation split, quantizes for the MCU
//! simulator and compares dense vs UnIT-pruned inference: MACs skipped,
//! modeled MSP430 cycles, time and energy.

use unit_pruner::approx::DivShift;
use unit_pruner::data::{by_name, Sizes};
use unit_pruner::engine::{infer, EngineConfig, QModel};
use unit_pruner::mcu::EnergyModel;
use unit_pruner::models::{zoo, Params};
use unit_pruner::pruning::{calibrate, CalibConfig};
use unit_pruner::util::table::Table;

fn main() {
    // 1. A Table-1 model (paper architectures: mnist/cifar/kws/widar).
    let def = zoo("mnist");
    println!("model: {} {:?} -> {} classes, {} dense MACs", def.name, def.input_shape, def.classes, def.total_dense_macs());

    // 2. Weights: random here for speed — see examples/train_and_deploy.rs
    //    for real training through the AOT artifact.
    let params = Params::random(&def, 7);

    // 3. Synthetic data + one-time threshold calibration (paper §2.1):
    //    per-layer 20th percentile of |activation x weight| products.
    let ds = by_name("mnist", 42, Sizes { train: 16, val: 32, test: 8 });
    let thresholds = calibrate(&def, &params, &ds.val, &CalibConfig::default());
    println!("calibrated thresholds: {:?}\n", thresholds.per_layer);

    // 4. Quantize for the MCU (int8 weights, Q8.8 activations) and bake
    //    the thresholds in.
    let q_dense = QModel::quantize(&def, &params);
    let q_unit = q_dense.clone().with_thresholds(&thresholds);

    // 5. Run one inference each way on the MSP430 simulator.
    let x = q_dense.quantize_input(ds.test.sample(0));
    let energy = EnergyModel::default();
    let mut t = Table::new(vec!["config", "MACs kept", "MACs skipped", "cycles", "time ms", "energy mJ"]);
    for (name, q, cfg) in [
        ("dense", &q_dense, EngineConfig::dense(&DivShift)),
        ("UnIT", &q_unit, EngineConfig::unit(&DivShift)),
    ] {
        let out = infer(q, &x, &cfg);
        t.row(vec![
            name.to_string(),
            out.kept.iter().sum::<u64>().to_string(),
            format!("{} ({:.1}%)", out.skipped.iter().sum::<u64>(), 100.0 * out.skip_fraction()),
            out.ledger.total_cycles().to_string(),
            format!("{:.1}", 1e3 * out.ledger.secs()),
            format!("{:.3}", out.ledger.millijoules(&energy)),
        ]);
    }
    println!("{}", t.render());
    println!("(the pruning decisions above used zero multiplications — only\n comparisons against T/|control| with an approximate division, Eq. 1-3)");
}
