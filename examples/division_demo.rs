//! Fast division approximation demo (paper §2.2, Figs. 3/4 + Eq. 5/6).
//!
//! ```text
//! cargo run --release --example division_demo
//! ```
//!
//! Shows each estimator's answer, error and modeled MSP430 cycle cost on
//! a few concrete threshold/control pairs, plus the IEEE-754 bit-mask
//! trick on host floats.

use unit_pruner::approx::{DivApprox, DivExact, DivKind, DivMask};
use unit_pruner::util::table::Table;

fn main() {
    println!("UnIT pruning needs T/|c| — never a multiplication (Eq. 1):\n");
    let cases: [(u32, u32); 5] = [(5120, 37), (5120, 512), (40_000, 3), (999, 1000), (70_000, 255)];
    let mut t = Table::new(vec!["t", "c", "exact t/c", "shift", "tree", "mask", "cycles e/s/t/m"]);
    for (tt, c) in cases {
        let mut vals = Vec::new();
        let mut cyc = Vec::new();
        for kind in DivKind::all() {
            let d = kind.build();
            vals.push(d.div(tt, c));
            cyc.push(d.cycles(tt, c).to_string());
        }
        t.row(vec![
            tt.to_string(),
            c.to_string(),
            (tt / c).to_string(),
            vals[1].to_string(),
            vals[2].to_string(),
            vals[3].to_string(),
            cyc.join("/"),
        ]);
    }
    println!("{}", t.render());
    println!("exact division is modeled at {} cycles (software routine);", DivExact.cycles(1, 1));
    println!("shift/tree find floor(log2 c) and return t >> e (paper Figs. 3-4);");
    println!("mask keeps only the exponent fields: t/c ~ 2^(Et-Ec) (Eq. 6).\n");

    println!("IEEE-754 bit masking on host floats (Eq. 5/6):");
    for (x, tt) in [(8.0f32, 2.0f32), (100.0, 3.0), (0.5, 4.0)] {
        println!(
            "  {x:>6} / {tt} = {:<10} bit-mask estimate: {}",
            x / tt,
            DivMask::div_f32(x, tt)
        );
    }
}
