//! Streamed-serving load generator: N concurrent clients over TCP with
//! mixed batches, deadlines, and cancellations — and hard assertions
//! that nothing is lost or misordered.
//!
//! ```text
//! # against a running server (CI serve-smoke drives it this way):
//! unit serve --listen 127.0.0.1:0 --workers 4 &   # prints the bound addr
//! cargo run --release --example stream_clients -- --addr 127.0.0.1:PORT
//!
//! # fully self-contained (spawns its own in-process server):
//! cargo run --release --example stream_clients -- --in-process
//! ```
//!
//! Exit status is the test: 0 iff every uncancelled, unexpired request
//! produced exactly its expected `Ok` responses in strict slot order,
//! cancelled requests produced only an ordered prefix, and every
//! request-level status was accounted for.
//!
//! `--retry` switches every client to the self-healing
//! [`RetryClient`]: sequential requests that reconnect and resubmit
//! through `Rejected`/`Failed` outcomes — the mode the CI chaos-smoke
//! job drives against `unit serve --chaos-seed`, where injected worker
//! panics, corrupted frames, and stalls are expected and every request
//! must still land.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use unit_pruner::approx::DivKind;
use unit_pruner::coordinator::{BackendChoice, Coordinator, ServeConfig};
use unit_pruner::data::{by_name, Sizes};
use unit_pruner::engine::{PruneMode, QModel};
use unit_pruner::models::{zoo, Params};
use unit_pruner::pruning::{calibrate, CalibConfig};
use unit_pruner::serve::{
    Client, RetryCfg, RetryClient, ServeOpts, Server, SessionCfg, Status, WHOLE_REQUEST,
};
use unit_pruner::util::cli::Args;
use unit_pruner::util::Rng;

#[derive(Default)]
struct Tally {
    ok: AtomicU64,
    rejected: AtomicU64,
    expired: AtomicU64,
    errors: AtomicU64,
    cancelled: AtomicU64,
    /// Requests answered `Failed` (a contained worker panic). Retries
    /// absorb these in `--retry` mode; the plain pipelined client just
    /// counts them.
    failed: AtomicU64,
    violations: AtomicU64,
}

fn main() -> Result<()> {
    let args = Args::from_env();
    let model = args.get_or("model", "mnist").to_string();
    let n_clients = args.usize_or("clients", 4);
    let n_requests = args.usize_or("requests", 12);
    let max_batch = args.usize_or("batch", 6).max(1);
    let deadline_frac = args.f64_or("deadline-frac", 0.15);
    let cancel_frac = args.f64_or("cancel-frac", 0.15);
    let seed = args.u64_or("seed", 42);
    let retry = args.flag("retry");

    let def = zoo(&model);
    let ds = by_name(&model, seed, Sizes::default());
    let classes = def.classes;

    // Either connect to a running `unit serve --listen`, or spawn an
    // in-process server (random weights: the protocol under test does
    // not care about accuracy).
    let own_server: Option<Server>;
    let addr: String = match args.get("addr") {
        Some(a) => {
            own_server = None;
            a.to_string()
        }
        None => {
            if !args.flag("in-process") {
                eprintln!("stream_clients: pass --addr HOST:PORT or --in-process");
                std::process::exit(2);
            }
            let params = Params::random(&def, seed);
            let th = calibrate(&def, &params, &ds.val, &CalibConfig::default());
            let q = QModel::quantize(&def, &params).with_thresholds(&th);
            let coord = Coordinator::start(
                BackendChoice::McuSim { q, mode: PruneMode::Unit, div: DivKind::Shift },
                ServeConfig { workers: args.usize_or("workers", 4), ..Default::default() },
            );
            let server = Server::start(
                coord,
                "127.0.0.1:0",
                ServeOpts {
                    max_conns: n_clients + 4,
                    session: SessionCfg {
                        max_inflight: args.usize_or("window", 32),
                        park: args.usize_or("park", 0),
                        ..Default::default()
                    },
                    ..Default::default()
                },
            )?;
            let a = server.local_addr().to_string();
            own_server = Some(server);
            a
        }
    };
    println!(
        "stream_clients: {n_clients} clients x {n_requests} requests -> {addr} \
         (batch <= {max_batch}, deadline {:.0}%, cancel {:.0}%{})",
        deadline_frac * 100.0,
        cancel_frac * 100.0,
        if retry { ", retry mode" } else { "" },
    );

    let tally = Arc::new(Tally::default());
    let t0 = std::time::Instant::now();
    let handles: Vec<_> = (0..n_clients)
        .map(|c| {
            let addr = addr.clone();
            let tally = Arc::clone(&tally);
            let samples: Vec<Vec<f32>> =
                (0..ds.test.len()).map(|i| ds.test.sample(i).to_vec()).collect();
            std::thread::spawn(move || {
                if retry {
                    client_run_retry(
                        c as u64, &addr, &samples, classes, n_requests, max_batch, &tally,
                    )
                } else {
                    client_run(
                        c as u64,
                        &addr,
                        &samples,
                        classes,
                        n_requests,
                        max_batch,
                        deadline_frac,
                        cancel_frac,
                        &tally,
                    )
                }
            })
        })
        .collect();
    for h in handles {
        h.join().expect("client thread panicked");
    }
    let dt = t0.elapsed().as_secs_f64();

    let (ok, rej, exp, err, can, fail, bad) = (
        tally.ok.load(Ordering::Relaxed),
        tally.rejected.load(Ordering::Relaxed),
        tally.expired.load(Ordering::Relaxed),
        tally.errors.load(Ordering::Relaxed),
        tally.cancelled.load(Ordering::Relaxed),
        tally.failed.load(Ordering::Relaxed),
        tally.violations.load(Ordering::Relaxed),
    );
    println!(
        "done in {dt:.2}s: {ok} ok samples ({:.0} samp/s), {rej} rejected, {exp} expired, \
         {can} cancelled, {fail} failed, {err} errors, {bad} protocol violations",
        ok as f64 / dt
    );
    if let Some(server) = own_server {
        server.shutdown();
    }
    if bad > 0 {
        eprintln!("FAIL: {bad} lost/misordered/duplicated responses");
        std::process::exit(1);
    }
    println!("OK: zero lost or misordered responses");
    Ok(())
}

#[allow(clippy::too_many_arguments)]
fn client_run(
    client_id: u64,
    addr: &str,
    samples: &[Vec<f32>],
    classes: usize,
    n_requests: usize,
    max_batch: usize,
    deadline_frac: f64,
    cancel_frac: f64,
    tally: &Tally,
) {
    let client = match Client::connect(addr) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("client {client_id}: connect {addr}: {e}");
            tally.violations.fetch_add(1, Ordering::Relaxed);
            return;
        }
    };
    let mut rng = Rng::new(0x57EA_4000 + client_id);
    if !client.ping(Duration::from_secs(5)) {
        eprintln!("client {client_id}: no pong");
        tally.violations.fetch_add(1, Ordering::Relaxed);
        return;
    }
    // Phase 1 — pipeline every request onto the wire (this is what
    // pushes sessions into their in-flight window under load), issuing
    // mid-flight cancels as we go.
    struct Issued {
        id: u64,
        n: usize,
        rx: std::sync::mpsc::Receiver<unit_pruner::serve::WireResponse>,
        cancel: bool,
        tight_deadline: bool,
    }
    let mut issued = Vec::with_capacity(n_requests);
    for _ in 0..n_requests {
        let n = 1 + rng.below(max_batch as u64) as usize;
        let xs: Vec<Vec<f32>> = (0..n)
            .map(|_| samples[rng.below(samples.len() as u64) as usize].clone())
            .collect();
        // A 1 ms deadline under concurrent load: sometimes met,
        // usually expired — both legal outcomes, checked for shape.
        let tight_deadline = rng.chance(deadline_frac);
        let deadline = tight_deadline.then(|| Duration::from_millis(1));
        let cancel = !tight_deadline && rng.chance(cancel_frac);
        let (id, rx) = match client.submit_batch(&xs, deadline) {
            Ok(v) => v,
            Err(e) => {
                eprintln!("client {client_id}: submit: {e}");
                tally.violations.fetch_add(1, Ordering::Relaxed);
                return;
            }
        };
        if cancel {
            // Let a prefix land, then cut the rest off mid-batch.
            std::thread::sleep(Duration::from_micros(rng.below(2000)));
            let _ = client.cancel(id);
        }
        issued.push(Issued { id, n, rx, cancel, tight_deadline });
    }
    // Phase 2 — drain and validate each request's event stream.
    for Issued { id, n, rx, cancel, tight_deadline } in issued {
        let mut next_slot = 0u32;
        let mut terminal: Option<Status> = None;
        let mut violated = false;
        loop {
            // A cancelled request's tail is silence; don't wait long
            // for it. (The loopback e2e test does the rigorous
            // post-cancel silence check.)
            let patience =
                if cancel { Duration::from_millis(500) } else { Duration::from_secs(30) };
            match rx.recv_timeout(patience) {
                Ok(ev) if ev.status == Status::Ok && ev.slot != WHOLE_REQUEST => {
                    if ev.slot != next_slot || ev.logits.len() != classes {
                        violated = true;
                        break;
                    }
                    next_slot += 1;
                    tally.ok.fetch_add(1, Ordering::Relaxed);
                    if next_slot as usize == n {
                        break;
                    }
                }
                Ok(ev) => {
                    terminal = Some(ev.status);
                    break;
                }
                Err(_) => {
                    // Quiet: legal only after a cancel (suppressed tail).
                    break;
                }
            }
        }
        let complete = next_slot as usize == n;
        match terminal {
            Some(Status::Rejected) => {
                tally.rejected.fetch_add(1, Ordering::Relaxed);
                if next_slot != 0 {
                    violated = true; // rejection must precede any result
                }
            }
            Some(Status::Expired) => {
                tally.expired.fetch_add(1, Ordering::Relaxed);
                if !tight_deadline {
                    violated = true; // only deadline'd requests may expire
                }
            }
            Some(Status::Failed) => {
                // A worker panic was contained mid-request: a terminal
                // outcome, not a violation (the `--retry` mode is the
                // one that resubmits these).
                tally.failed.fetch_add(1, Ordering::Relaxed);
            }
            Some(Status::Error) | Some(Status::Cancelled) => {
                tally.errors.fetch_add(1, Ordering::Relaxed);
            }
            Some(Status::Ok) | None => {
                if !complete && !cancel && !tight_deadline {
                    violated = true; // lost responses
                }
                if !complete && cancel {
                    tally.cancelled.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
        if violated {
            eprintln!(
                "client {client_id}: request {id}: violation at slot {next_slot}/{n} \
                 (terminal {terminal:?}, cancel={cancel}, deadline={tight_deadline})"
            );
            tally.violations.fetch_add(1, Ordering::Relaxed);
        }
    }
    client.goodbye(Duration::from_secs(10));
}

/// `--retry` mode: sequential requests through the self-healing
/// [`RetryClient`]. Under chaos injection every request must still end
/// `Ok` (or `Expired`, its deadline respected) — reconnects and
/// resubmits are the client's job, slot order and completeness are
/// still hard-asserted.
fn client_run_retry(
    client_id: u64,
    addr: &str,
    samples: &[Vec<f32>],
    classes: usize,
    n_requests: usize,
    max_batch: usize,
    tally: &Tally,
) {
    let cfg = RetryCfg { max_attempts: 32, seed: 0xC1A0_0000 + client_id, ..Default::default() };
    let client = RetryClient::connect(addr, cfg);
    let mut rng = Rng::new(0x57EA_8000 + client_id);
    for _ in 0..n_requests {
        let n = 1 + rng.below(max_batch as u64) as usize;
        let xs: Vec<Vec<f32>> = (0..n)
            .map(|_| samples[rng.below(samples.len() as u64) as usize].clone())
            .collect();
        match client.infer_batch(&xs, Some(Duration::from_secs(60))) {
            Ok(events) => {
                if events.len() == 1 && events[0].status == Status::Expired {
                    tally.expired.fetch_add(1, Ordering::Relaxed);
                    continue;
                }
                let ordered = events.iter().enumerate().all(|(i, ev)| {
                    ev.status == Status::Ok && ev.slot as usize == i && ev.logits.len() == classes
                });
                if events.len() == n && ordered {
                    tally.ok.fetch_add(events.len() as u64, Ordering::Relaxed);
                } else {
                    eprintln!(
                        "client {client_id}: retry result malformed ({} events for {n} samples)",
                        events.len()
                    );
                    tally.violations.fetch_add(1, Ordering::Relaxed);
                }
            }
            Err(e) => {
                eprintln!("client {client_id}: retry budget exhausted: {e}");
                tally.violations.fetch_add(1, Ordering::Relaxed);
            }
        }
    }
}
