//! Multi-model serving under one fleet budget, end to end over the
//! wire: two zoo models behind a single coordinator, the fleet
//! scheduler dividing a global energy budget between them by marginal
//! keep-per-millijoule, and wire-v4 clients addressing each tenant by
//! model id.
//!
//! ```text
//! # self-contained (spawns its own loopback fleet server):
//! cargo run --release --example multi_model_serve
//!
//! # against a running `unit serve --listen ... --models mnist,kws`:
//! cargo run --release --example multi_model_serve -- --addr 127.0.0.1:PORT
//! ```
//!
//! Exit status is the test: 0 iff
//! * the server reports ≥ 2 models loaded and a live fleet budget,
//! * interleaved per-model traffic completed losslessly with every
//!   reply routed back to the submitting request,
//! * starving the fleet budget pushed at least one tenant up its scale
//!   grid, and budget relief brought the fleet back down.

use std::time::Duration;

use anyhow::Result;

use unit_pruner::approx::DivKind;
use unit_pruner::control::{calibrated_cache, FleetScheduler, ScaleGrid};
use unit_pruner::coordinator::{Coordinator, ModelSpec, ServeConfig};
use unit_pruner::data::{by_name, Sizes, Split};
use unit_pruner::engine::{PlanConfig, PruneMode, QModel};
use unit_pruner::models::{zoo, Params};
use unit_pruner::pruning::Thresholds;
use unit_pruner::serve::{Client, ServeOpts, Server, Status};
use unit_pruner::util::cli::Args;
use unit_pruner::util::table::Table;

const MODELS: &[&str] = &["mnist", "kws"];

fn main() -> Result<()> {
    let args = Args::from_env();
    let seed = args.u64_or("seed", 42);
    let per_model = args.usize_or("requests", 32);

    // Per-model sample pools (the fleet server's tenants expect their
    // own input lengths — submitting a sample to the wrong model id is
    // an Error status, which this example treats as a violation).
    let pools: Vec<Split> =
        MODELS.iter().map(|m| by_name(m, seed, Sizes::default()).test).collect();

    // Either connect to a running fleet server, or spawn one.
    let own_server: Option<Server>;
    let addr: String = match args.get("addr") {
        Some(a) => {
            own_server = None;
            a.to_string()
        }
        None => {
            let mut specs = Vec::new();
            let mut tenants = Vec::new();
            for name in MODELS {
                let def = zoo(name);
                let params = Params::random(&def, seed);
                let q = QModel::quantize(&def, &params)
                    .with_thresholds(&Thresholds::uniform(def.layers.len(), 0.15));
                let ds = by_name(name, seed, Sizes::default());
                let cal: Vec<Vec<f32>> =
                    (0..ds.val.len().min(6)).map(|i| ds.val.sample(i).to_vec()).collect();
                let (cache, profile) = calibrated_cache(
                    q.clone(),
                    PlanConfig::unit(DivKind::Shift),
                    ScaleGrid::default_grid(),
                    &cal,
                );
                specs.push(ModelSpec {
                    name: name.to_string(),
                    q,
                    mode: PruneMode::Unit,
                    div: DivKind::Shift,
                });
                tenants.push((cache, profile));
            }
            // Budget = every tenant's 1.0x-scale energy summed: roomy,
            // so the scheduler seeds near the top of each curve.
            let base_mj: f64 =
                tenants.iter().map(|(c, p)| p.mean_mj(c.grid().snap_q8(256))).sum();
            let coord = Coordinator::start_multi(
                specs,
                ServeConfig { workers: args.usize_or("workers", 2), ..Default::default() },
            );
            let sched = FleetScheduler::install(&coord, tenants, base_mj)
                .expect("fleet scheduler on mcu backend");
            let server = Server::start(
                coord,
                "127.0.0.1:0",
                ServeOpts { scheduler: Some(sched), ..Default::default() },
            )?;
            let a = server.local_addr().to_string();
            own_server = Some(server);
            a
        }
    };

    let client = Client::connect(&addr)?;
    let probe = client.query_stats(Duration::from_secs(10))?;
    if probe.models_loaded < 2 || probe.fleet_budget_mj <= 0.0 {
        eprintln!(
            "multi_model_serve: server at {addr} is not a fleet \
             ({} models, fleet budget {} mJ) — run `unit serve --models A,B --listen …`",
            probe.models_loaded, probe.fleet_budget_mj
        );
        std::process::exit(2);
    }
    let n_models = (probe.models_loaded as usize).min(pools.len());
    let base_mj = probe.fleet_budget_mj;
    println!(
        "multi_model_serve: {addr}, {} models, fleet budget {base_mj:.3} mJ",
        probe.models_loaded
    );

    // Fleet budget sweep: generous → starved → relief, with traffic to
    // every tenant interleaved inside each phase.
    let phases: &[(&str, f64)] = &[("generous", 1.0), ("starved", 0.05), ("relief", 1.0)];
    let mut t = Table::new(vec!["phase", "fleet mJ", "model", "scale", "step", "cap mJ"]);
    let mut violations = 0usize;
    let mut step_sums = Vec::new();
    for (phase, mult) in phases {
        let budget = base_mj * mult;
        client.set_budget(budget, Duration::from_secs(10))?;
        // Interleave tenants request-by-request: ordering and loss
        // accounting must hold under mixed-tenant load.
        let mut rxs = Vec::new();
        for r in 0..per_model {
            for (m, pool) in pools.iter().enumerate().take(n_models) {
                let x = pool.sample(r % pool.len());
                let (id, rx) = client.submit_to(m as u32, x, None)?;
                rxs.push((m, id, rx));
            }
        }
        for (m, id, rx) in rxs {
            let ev = rx.recv_timeout(Duration::from_secs(60))?;
            if ev.status != Status::Ok {
                eprintln!("{phase}: model {m} request {id} got {:?}", ev.status);
                violations += 1;
            }
        }
        let mut sum = 0u64;
        for m in 0..n_models as u32 {
            let s = client.query_model_stats(m, Duration::from_secs(10))?;
            sum += s.step as u64;
            t.row(vec![
                phase.to_string(),
                format!("{budget:.3}"),
                m.to_string(),
                format!("{:.2}x", s.scale()),
                format!("{}/{}", s.step, s.steps_total),
                format!("{:.3}", s.budget_mj),
            ]);
        }
        step_sums.push(sum);
    }
    println!("{}", t.render());
    client.goodbye(Duration::from_secs(10));
    if let Some(server) = own_server {
        server.shutdown();
    }

    // Direction assertions on the summed allocation: starving the
    // fleet must push tenants up the grid, relief must bring them back.
    let (generous, starved, relief) = (step_sums[0], step_sums[1], step_sums[2]);
    if starved <= generous {
        eprintln!("FAIL: starving the fleet did not raise any tenant ({generous} -> {starved})");
        violations += 1;
    }
    if relief >= starved {
        eprintln!("FAIL: fleet relief did not lower the allocation ({starved} -> {relief})");
        violations += 1;
    }
    if violations > 0 {
        eprintln!("FAIL: {violations} violations");
        std::process::exit(1);
    }
    println!("OK: lossless mixed-tenant serving; the fleet allocation tracked the budget");
    Ok(())
}
