//! Energy-adaptive inference (paper §6.1): UnIT's aggressiveness as a
//! runtime knob on a harvested-power device.
//!
//! ```text
//! cargo run --release --example adaptive_energy
//! ```
//!
//! Simulates a day of fluctuating harvest: the per-inference energy
//! budget swings between generous (2× dense cost) and starved (0.3×).
//! The [`EnergyController`] watches the ledger after every inference and
//! scales all UnIT thresholds up/down so measured energy tracks the
//! budget — trading accuracy only when the harvester forces it, with no
//! retraining and no model swap.

use unit_pruner::approx::DivShift;
use unit_pruner::coordinator::EnergyController;
use unit_pruner::data::{by_name, Sizes};
use unit_pruner::engine::{infer, EngineConfig, PruneMode, QModel};
use unit_pruner::mcu::EnergyModel;
use unit_pruner::models::{zoo, Params};
use unit_pruner::pruning::{calibrate, CalibConfig};
use unit_pruner::util::table::Table;

fn main() {
    let def = zoo("mnist");
    let ds = by_name("mnist", 42, Sizes::default());
    // Use cached trained weights when available (run `unit train` or the
    // train_and_deploy example first); fall back to random weights so the
    // demo stays artifact-free.
    let store = unit_pruner::runtime::ArtifactStore::discover();
    let params = Params::load(&store.weights_path("mnist"))
        .unwrap_or_else(|_| Params::random(&def, 7));
    let th = calibrate(&def, &params, &ds.val, &CalibConfig::default());
    let q = QModel::quantize(&def, &params).with_thresholds(&th);
    let energy = EnergyModel::default();

    // Measure the dense cost once to express budgets in natural units.
    let dense_mj = {
        let out = infer(
            &q,
            &q.quantize_input(ds.test.sample(0)),
            &EngineConfig::dense(&DivShift),
        );
        out.ledger.millijoules(&energy)
    };
    println!("dense inference costs {dense_mj:.2} mJ; running adaptive loop\n");

    // Harvest phases: (budget multiplier, #inferences).
    let phases = [("morning sun", 2.0, 60), ("clouds", 0.8, 60), ("night", 0.35, 80), ("recovery", 1.2, 60)];
    let mut ctrl = EnergyController::new(dense_mj);
    let mut t = Table::new(vec![
        "phase",
        "budget mJ",
        "mean mJ",
        "final scale",
        "mean skip %",
        "accuracy",
    ]);
    let mut idx = 0usize;
    for (name, mult, steps) in phases {
        ctrl.set_budget(dense_mj * mult);
        let mut mj_sum = 0.0;
        let mut skip_sum = 0.0;
        let mut hits = 0usize;
        for _ in 0..steps {
            let i = idx % ds.test.len();
            idx += 1;
            let cfg = EngineConfig {
                mode: PruneMode::Unit,
                div: &DivShift,
                sonic_accumulators: true,
                precomputed_conv_thresholds: false,
                t_scale_q8: ctrl.t_scale_q8(),
            };
            let out = infer(&q, &q.quantize_input(ds.test.sample(i)), &cfg);
            let mj = out.ledger.millijoules(&energy);
            ctrl.observe(mj);
            mj_sum += mj;
            skip_sum += out.skip_fraction();
            hits += (out.argmax() == ds.test.y[i]) as usize;
        }
        t.row(vec![
            name.to_string(),
            format!("{:.2}", dense_mj * mult),
            format!("{:.2}", mj_sum / steps as f64),
            format!("{:.2}x", ctrl.scale()),
            format!("{:.1}%", 100.0 * skip_sum / steps as f64),
            format!("{:.1}%", 100.0 * hits as f64 / steps as f64),
        ]);
    }
    println!("{}", t.render());
    println!("night phase: the controller prunes harder (higher scale, more skips)\nto live within the harvested budget; recovery relaxes automatically.");
}
