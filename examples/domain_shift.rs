//! Domain-shift demo (paper §4.2 / Table 2, condensed): train the Widar
//! gesture model in Room 1, deploy in Room 2, and watch UnIT hold F1
//! while skipping more MACs than train-time pruning.
//!
//! ```text
//! make artifacts && cargo run --release --example domain_shift
//! ```

use anyhow::Result;
use unit_pruner::data::widar_like::{generate_room, Room};
use unit_pruner::data::Sizes;
use unit_pruner::models::zoo;
use unit_pruner::nn::ForwardOpts;
use unit_pruner::pruning::{apply_global_magnitude, calibrate, CalibConfig};
use unit_pruner::runtime::{ArtifactStore, Runtime};
use unit_pruner::train::{ensure_trained_tagged, evaluate_float, TrainConfig};
use unit_pruner::util::table::Table;

fn main() -> Result<()> {
    let rt = Runtime::cpu()?;
    let store = ArtifactStore::discover();
    let def = zoo("widar");
    let sizes = Sizes::default();

    println!("training in Room 1 (cluttered classroom)...");
    let ds_r1 = generate_room(42, sizes, Room::Room1);
    let params = ensure_trained_tagged(
        &rt,
        &store,
        "widar",
        "widar-room1",
        &ds_r1,
        &TrainConfig::for_model("widar"),
    )?;
    let params_ttp = apply_global_magnitude(&params, 0.5);
    let th = calibrate(&def, &params, &ds_r1.val, &CalibConfig::default());

    println!("deploying in Room 2 (empty hallway) — distribution shift\n");
    let ds_r2 = generate_room(42, sizes, Room::Room2);
    let nl = def.layers.len();
    let mut t = Table::new(vec!["mechanism", "F1 (room2)", "MACs skipped"]);
    for (name, p, tv) in [
        ("Unpruned", &params, vec![0.0; nl]),
        ("TTP (50%)", &params_ttp, vec![0.0; nl]),
        ("UnIT", &params, th.per_layer.clone()),
    ] {
        let r = evaluate_float(&def, p, &ds_r2.test, &ForwardOpts { t_vec: tv, fat_t: 0.0 }, 200);
        t.row(vec![
            name.to_string(),
            format!("{:.4}", r.macro_f1),
            format!("{:.2}%", 100.0 * r.mac_skipped),
        ]);
    }
    println!("{}", t.render());
    println!("UnIT's thresholds adapt per input, so pruning decisions follow the\nshifted activations — no retraining, unlike a static train-time mask.");
    Ok(())
}
