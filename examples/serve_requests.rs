//! Serving demo: the Layer-3 coordinator under load on both backends.
//!
//! ```text
//! make artifacts && cargo run --release --example serve_requests
//! ```
//!
//! Submits a burst of requests to (a) the MCU-simulator work-stealing
//! worker pool with UnIT pruning and (b) the PJRT float backend with
//! dynamic batching, and reports throughput, latency percentiles —
//! queue wait and service time separately — and (for the MCU) the
//! modeled on-device cost of each answer. The MCU burst mixes single
//! submissions with one large batched request that is split across the
//! worker shards and reassembled in input order.

use anyhow::Result;
use std::time::Duration;

use unit_pruner::approx::DivKind;
use unit_pruner::coordinator::{BackendChoice, Coordinator, ServeConfig};
use unit_pruner::data::{by_name, Sizes};
use unit_pruner::engine::{PruneMode, QModel};
use unit_pruner::models::zoo;
use unit_pruner::pruning::{calibrate, CalibConfig};
use unit_pruner::runtime::{ArtifactStore, Runtime};
use unit_pruner::train::{ensure_trained, TrainConfig};

fn main() -> Result<()> {
    let model = "mnist";
    let n_req = 64usize;
    let rt = Runtime::cpu()?;
    let store = ArtifactStore::discover();
    let def = zoo(model);
    let ds = by_name(model, 42, Sizes::default());
    let params = ensure_trained(&rt, &store, model, &ds, &TrainConfig::for_model(model))?;
    let th = calibrate(&def, &params, &ds.val, &CalibConfig::default());

    for backend in ["mcu", "pjrt"] {
        println!("=== backend: {backend} ===");
        let choice = match backend {
            "mcu" => BackendChoice::McuSim {
                q: QModel::quantize(&def, &params).with_thresholds(&th),
                mode: PruneMode::Unit,
                div: DivKind::Shift,
            },
            _ => BackendChoice::Pjrt {
                model: model.into(),
                params: params.clone(),
                t_vec: th.per_layer.clone(),
                fat_t: 0.0,
            },
        };
        let coord = Coordinator::start(
            choice,
            ServeConfig {
                workers: 2,
                max_batch: 8,
                max_wait: Duration::from_millis(2),
                ..Default::default()
            },
        );
        let t0 = std::time::Instant::now();
        // Half the load as one batched request (split across the worker
        // shards on the MCU backend), half as singles.
        let n_batch = if backend == "mcu" { n_req / 2 } else { 0 };
        let batch_rx = (n_batch > 0).then(|| {
            coord.submit_batch(
                (0..n_batch).map(|i| ds.test.sample(i % ds.test.len()).to_vec()).collect(),
            )
        });
        let rxs: Vec<_> = (n_batch..n_req)
            .map(|i| coord.submit(ds.test.sample(i % ds.test.len()).to_vec()))
            .collect();
        let mut hits = 0usize;
        if let Some(rx) = batch_rx {
            for (i, resp) in rx.recv()?.into_iter().enumerate() {
                hits += (resp.predicted == ds.test.y[i % ds.test.len()]) as usize;
            }
        }
        for (i, rx) in rxs.into_iter().enumerate() {
            let resp = rx.recv()?;
            hits += (resp.predicted == ds.test.y[(n_batch + i) % ds.test.len()]) as usize;
        }
        let dt = t0.elapsed().as_secs_f64();
        let s = coord.metrics.snapshot();
        coord.shutdown();
        println!(
            "  {} req in {:.3}s -> {:.1} req/s | accuracy {:.1}% | p50/p95/p99 {}/{}/{} us | mean batch {:.2}",
            s.served,
            dt,
            n_req as f64 / dt,
            100.0 * hits as f64 / n_req as f64,
            s.p50_us,
            s.p95_us,
            s.p99_us,
            s.mean_batch
        );
        println!(
            "  queue wait p50/p99 {}/{} us | service p50/p99 {}/{} us",
            s.queue_p50_us, s.queue_p99_us, s.service_p50_us, s.service_p99_us
        );
        if backend == "mcu" {
            println!(
                "  modeled per-inference on MSP430: {:.2}% MACs skipped, {:.3} mJ, {:.3} s",
                100.0 * s.mean_mac_skipped,
                s.mean_energy_mj,
                s.mean_mcu_secs
            );
        }
        println!();
    }
    Ok(())
}
