//! Budget-driven adaptive serving, end to end over the wire: sweep the
//! energy budget on a live server and watch the governor move the
//! threshold scale — plans served from the scale-indexed cache, never
//! recompiled on revisits.
//!
//! ```text
//! # self-contained (spawns its own loopback server + governor):
//! cargo run --release --example adaptive_serve -- --in-process
//!
//! # against a running `unit serve --listen ... --budget-mj B`:
//! cargo run --release --example adaptive_serve -- --addr 127.0.0.1:PORT --base-mj 4.0
//! ```
//!
//! Exit status is the test: 0 iff
//! * every request completed losslessly and in order,
//! * starving the budget RAISED the scale step and budget relief
//!   LOWERED it (the §6.1 direction),
//! * revisiting an already-visited scale regime was cache-served (the
//!   miss counter stopped growing).

use std::time::Duration;

use anyhow::Result;

use unit_pruner::approx::DivKind;
use unit_pruner::control::{calibrated_cache, Governor, ScaleGrid};
use unit_pruner::coordinator::{BackendChoice, Coordinator, ServeConfig};
use unit_pruner::data::{by_name, Sizes};
use unit_pruner::engine::{PlanConfig, PruneMode, QModel};
use unit_pruner::models::{zoo, Params};
use unit_pruner::pruning::Thresholds;
use unit_pruner::serve::{Client, ServeOpts, Server, Status};
use unit_pruner::util::cli::Args;
use unit_pruner::util::table::Table;

fn main() -> Result<()> {
    let args = Args::from_env();
    let model = args.get_or("model", "mnist").to_string();
    let seed = args.u64_or("seed", 42);
    let per_phase = args.usize_or("requests", 48);

    let def = zoo(&model);
    let ds = by_name(&model, seed, Sizes::default());

    // Either connect to a running adaptive server, or spawn one.
    let own_server: Option<Server>;
    let base_mj: f64;
    let addr: String = match args.get("addr") {
        Some(a) => {
            own_server = None;
            base_mj = args.f64_or("base-mj", 1.0);
            a.to_string()
        }
        None => {
            if !args.flag("in-process") {
                eprintln!("adaptive_serve: pass --addr HOST:PORT or --in-process");
                std::process::exit(2);
            }
            let params = Params::random(&def, seed);
            let q = QModel::quantize(&def, &params)
                .with_thresholds(&Thresholds::uniform(def.layers.len(), 0.15));
            let coord = Coordinator::start(
                BackendChoice::McuSim { q: q.clone(), mode: PruneMode::Unit, div: DivKind::Shift },
                ServeConfig { workers: args.usize_or("workers", 2), ..Default::default() },
            );
            let cal: Vec<Vec<f32>> =
                (0..ds.val.len().min(6)).map(|i| ds.val.sample(i).to_vec()).collect();
            let (cache, profile) = calibrated_cache(
                q,
                PlanConfig::unit(DivKind::Shift),
                ScaleGrid::default_grid(),
                &cal,
            );
            // Budgets are expressed relative to the calibrated energy
            // at scale 1.0.
            base_mj = profile.mean_mj(cache.grid().snap_q8(256));
            let governor = Governor::install(&coord, cache, Some(profile), base_mj)
                .expect("governor on mcu backend");
            let server = Server::start(
                coord,
                "127.0.0.1:0",
                ServeOpts { governor: Some(governor), ..Default::default() },
            )?;
            let a = server.local_addr().to_string();
            own_server = Some(server);
            a
        }
    };

    let client = Client::connect(&addr)?;
    let probe = client.query_stats(Duration::from_secs(10))?;
    if !probe.adaptive() {
        eprintln!("adaptive_serve: server at {addr} has no governor (run with --budget-mj)");
        std::process::exit(2);
    }
    println!(
        "adaptive_serve: {addr}, grid of {} steps, base energy {base_mj:.3} mJ",
        probe.steps_total
    );

    // Budget sweep: generous → starved → relief. The relief phase
    // revisits scales compiled on the way up, so the cache must serve
    // it hit-only.
    let phases: &[(&str, f64)] =
        &[("generous", 3.0), ("tight", 0.5), ("starved", 0.05), ("relief", 3.0)];
    let mut t = Table::new(vec![
        "phase", "budget mJ", "scale", "step", "ewma mJ", "swaps", "cache hit/miss",
        "bg pend/comp/upg",
    ]);
    let mut violations = 0usize;
    let mut steps_seen = Vec::new();
    let mut misses_seen = Vec::new();
    for (name, mult) in phases {
        let budget = base_mj * mult;
        client.set_budget(budget, Duration::from_secs(10))?;
        // Drive traffic so the governor observes energies and walks.
        for r in 0..per_phase {
            let x = ds.test.sample(r % ds.test.len());
            let (_id, rx) = client.submit(x, None)?;
            let ev = rx.recv_timeout(Duration::from_secs(60))?;
            if ev.status != Status::Ok {
                eprintln!("{name}: request {r} got {:?}", ev.status);
                violations += 1;
            }
        }
        let s = client.query_stats(Duration::from_secs(10))?;
        println!(
            "[{name}] budget {budget:.3} mJ -> scale {:.2}x (step {}/{}), ewma {:.3} mJ",
            s.scale(),
            s.step,
            s.steps_total,
            s.ewma_mj
        );
        t.row(vec![
            name.to_string(),
            format!("{budget:.3}"),
            format!("{:.2}x", s.scale()),
            format!("{}/{}", s.step, s.steps_total),
            format!("{:.3}", s.ewma_mj),
            s.swaps.to_string(),
            format!("{}/{}", s.cache_hits, s.cache_misses),
            format!("{}/{}/{}", s.bg_pending, s.bg_compiled, s.bg_upgrades),
        ]);
        steps_seen.push(s.step);
        misses_seen.push(s.cache_misses);
    }
    println!("{}", t.render());
    client.goodbye(Duration::from_secs(10));
    if let Some(server) = own_server {
        server.shutdown();
    }

    // Direction assertions: starved must sit above generous, relief
    // back below starved.
    let (generous, starved, relief) = (steps_seen[0], steps_seen[2], steps_seen[3]);
    if starved <= generous {
        eprintln!("FAIL: starving the budget did not raise the scale ({generous} -> {starved})");
        violations += 1;
    }
    if relief >= starved {
        eprintln!("FAIL: budget relief did not lower the scale ({starved} -> {relief})");
        violations += 1;
    }
    // The relief phase walks back through steps compiled on the way
    // up: the miss counter must not have grown.
    if misses_seen[3] > misses_seen[2] {
        eprintln!(
            "FAIL: revisited scales were recompiled ({} -> {} misses)",
            misses_seen[2], misses_seen[3]
        );
        violations += 1;
    }
    if violations > 0 {
        eprintln!("FAIL: {violations} violations");
        std::process::exit(1);
    }
    println!("OK: scale tracked the budget in both directions; revisits were cache-served");
    Ok(())
}
