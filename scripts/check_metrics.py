#!/usr/bin/env python3
"""Metrics docs gate: every exported metric family is documented.

Stdlib only. rust/src/obs/export.rs is the single place metric family
names may appear (the renderer takes them as string literals), so the
check is a grep, not a parse:

1. collect every `"unit_…"` string literal in export.rs;
2. fail unless each appears (backticked or plain) in
   docs/observability.md;
3. fail the reverse direction too: a `unit_…` name documented in the
   metric catalogue that export.rs no longer emits is a stale doc;
4. native-histogram shape: every exported `…_bucket` family must ship
   its `…_count` and `…_sum` companions (and vice versa — a stray
   `_count`/`_sum` without `_bucket` is a half-rendered histogram).

Run from the repo root: python3 scripts/check_metrics.py
"""

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
EXPORT = ROOT / "rust/src/obs/export.rs"
DOC = ROOT / "docs/observability.md"

# A metric family name as it appears as a Rust string literal. Label
# keys ("model", "layer", ...) and help text never match this shape.
LITERAL_RE = re.compile(r'"(unit_[a-z0-9_]+)"')
# The same names as documented in the catalogue (backticked).
DOC_RE = re.compile(r"`(unit_[a-z0-9_]+)`")


def main() -> int:
    exported = set(LITERAL_RE.findall(EXPORT.read_text(encoding="utf-8")))
    doc_text = DOC.read_text(encoding="utf-8")
    documented = set(DOC_RE.findall(doc_text))

    errors = []
    for name in sorted(exported - documented):
        errors.append(f"docs/observability.md: exported metric `{name}` is undocumented")
    for name in sorted(documented - exported):
        errors.append(
            f"docs/observability.md: documents `{name}`, which rust/src/obs/export.rs "
            "no longer emits"
        )

    # Prometheus histogram families come in triples: for every
    # `<fam>_bucket` the renderer must also emit `<fam>_count` and
    # `<fam>_sum`, and neither companion may exist without the buckets.
    for name in sorted(exported):
        for suffix, companions in (
            ("_bucket", ("_count", "_sum")),
            ("_count", ("_bucket", "_sum")),
            ("_sum", ("_bucket", "_count")),
        ):
            if not name.endswith(suffix):
                continue
            fam = name[: -len(suffix)]
            for comp in companions:
                if fam + comp not in exported:
                    errors.append(
                        f"rust/src/obs/export.rs: histogram family `{fam}` exports "
                        f"`{name}` but not `{fam}{comp}`"
                    )

    for e in errors:
        print(f"error: {e}", file=sys.stderr)
    print(f"checked {len(exported)} exported families, {len(documented)} documented; "
          f"{len(errors)} error(s)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
