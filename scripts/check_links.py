#!/usr/bin/env python3
"""Docs gate: relative links, anchors, and wire-protocol coverage.

Stdlib only. Two checks, both hard failures:

1. Every relative link / image in README.md and docs/*.md resolves to a
   real file, and every `#anchor` (same-file or cross-file) matches a
   heading in the target file under GitHub's slugification rules.
2. docs/wire-protocol.md names every `Frame` and `Status` variant
   declared in rust/src/serve/wire.rs, so the normative spec cannot
   silently fall behind the codec.

Run from the repo root: python3 scripts/check_links.py
"""

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent

DOC_FILES = sorted([ROOT / "README.md", *(ROOT / "docs").glob("*.md")])

LINK_RE = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)


def github_slug(heading: str) -> str:
    """GitHub's anchor slug: strip markup, lowercase, drop punctuation,
    spaces to hyphens."""
    text = heading.strip()
    text = re.sub(r"`([^`]*)`", r"\1", text)  # unwrap inline code
    text = re.sub(r"\[([^\]]*)\]\([^)]*\)", r"\1", text)  # unwrap links
    text = text.lower()
    text = re.sub(r"[^\w\- ]", "", text)
    return text.replace(" ", "-")


def anchors_of(path: Path) -> set:
    slugs = set()
    seen = {}
    for m in HEADING_RE.finditer(path.read_text(encoding="utf-8")):
        slug = github_slug(m.group(1))
        n = seen.get(slug, 0)
        seen[slug] = n + 1
        slugs.add(slug if n == 0 else f"{slug}-{n}")
    return slugs


def check_links() -> list:
    errors = []
    for doc in DOC_FILES:
        text = doc.read_text(encoding="utf-8")
        for m in LINK_RE.finditer(text):
            target = m.group(1)
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            path_part, _, anchor = target.partition("#")
            resolved = doc if not path_part else (doc.parent / path_part).resolve()
            rel = doc.relative_to(ROOT)
            if not resolved.exists():
                errors.append(f"{rel}: broken link {target!r} ({path_part} not found)")
                continue
            if anchor:
                if resolved.suffix != ".md":
                    continue  # only markdown files carry checkable anchors
                if anchor not in anchors_of(resolved):
                    errors.append(f"{rel}: broken anchor {target!r} (no heading slugs to #{anchor})")
    return errors


def check_protocol_coverage() -> list:
    errors = []
    wire = (ROOT / "rust/src/serve/wire.rs").read_text(encoding="utf-8")
    spec = (ROOT / "docs/wire-protocol.md").read_text(encoding="utf-8")

    def enum_variants(name: str) -> list:
        m = re.search(rf"pub enum {name}\b[^{{]*{{(.*?)^}}", wire, re.DOTALL | re.MULTILINE)
        if not m:
            return []
        return re.findall(r"^    (?:///.*\n    )*([A-Z]\w*)", m.group(1), re.MULTILINE)

    frames = enum_variants("Frame")
    statuses = enum_variants("Status")
    if not frames or not statuses:
        return [f"could not extract enums from wire.rs (frames={frames}, statuses={statuses})"]
    for kind, variants in (("Frame", frames), ("Status", statuses)):
        for v in variants:
            if not re.search(rf"`{re.escape(v)}`", spec):
                errors.append(f"docs/wire-protocol.md: {kind} variant `{v}` is undocumented")
    return errors


def main() -> int:
    errors = check_links() + check_protocol_coverage()
    for e in errors:
        print(f"error: {e}", file=sys.stderr)
    n_links = sum(len(LINK_RE.findall(p.read_text(encoding="utf-8"))) for p in DOC_FILES)
    print(f"checked {len(DOC_FILES)} files, {n_links} links; {len(errors)} error(s)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
